//! Batched plan-reuse execution (ROADMAP "Batched multi-matrix
//! execution" + "AIA-aware bin scheduling").
//!
//! [`BatchExecutor`] drives the engine's plan-reuse layer
//! ([`PlannedProduct`]) at application scope:
//!
//! - **Pipelined batches** — [`BatchExecutor::execute_batch`] plans a
//!   set of products on a dedicated planner thread and streams the
//!   numeric fills on the calling thread, so symbolic analysis of
//!   product *k+1* overlaps the numeric fill of product *k* (the
//!   host-side analogue of running the two phases on separate CUDA
//!   streams). The Table-I bins of every planned product are also packed
//!   onto the coordinator's stream model with
//!   [`schedule_lpt`], which lets the group-3 (global-table, AIA-heavy)
//!   bins co-schedule with the PWPR bins instead of serializing after
//!   them; the resulting [`Schedule`] lands in the [`BatchReport`].
//! - **Plan caching** — plans are keyed by the operands' structure
//!   hashes and shared: [`BatchExecutor::multiply_cached`] reuses across
//!   calls, and [`BatchExecutor::execute_batch`] dedupes repeated
//!   structures within a batch, consults the cache, and seeds it with
//!   the plans it builds — so iterative callers (MCL expansions, GNN
//!   epochs) pay the symbolic phase only when a structure is genuinely
//!   new. Hit/miss counts live in [`BatchStats`].
//!
//! Both paths produce output bit-identical to a cold
//! [`crate::spgemm::hash::multiply`].
//!
//! Note on units: the stream-model job weights are **intermediate-product
//! counts**, not milliseconds — see [`BatchExecutor::stream_schedule`].

use super::metrics::Metrics;
use super::scheduler::{schedule_lpt, Job, Schedule};
use crate::spgemm::hash::{pair_key_from_hashes, PlannedProduct};
use crate::sparse::Csr;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// How many planned-but-unfilled products the pipeline holds: the
/// planner thread runs at most this far ahead of the numeric fills,
/// bounding peak plan memory.
const PIPELINE_DEPTH: usize = 2;

/// Plans cached by [`BatchExecutor::multiply_cached`] before arbitrary
/// eviction kicks in (iterative workloads cycle over a handful of
/// structures; this only bounds pathological callers).
const CACHE_CAP: usize = 32;

/// Counters accumulated across a [`BatchExecutor`]'s lifetime.
#[derive(Clone, Debug, Default)]
pub struct BatchStats {
    /// Symbolic plans built (products whose structure was new).
    pub plans_built: usize,
    /// Numeric fills executed.
    pub fills: usize,
    /// Products (cached calls or batch members) served by an existing
    /// or batch-shared plan.
    pub plan_hits: usize,
    /// Products that had to build a plan.
    pub plan_misses: usize,
    /// Wall seconds spent building plans (grouping + symbolic).
    pub plan_s: f64,
    /// Wall seconds spent in numeric fills.
    pub fill_s: f64,
}

impl BatchStats {
    /// Fraction of products served without replanning.
    pub fn hit_rate(&self) -> f64 {
        let total = self.plan_hits + self.plan_misses;
        if total == 0 {
            0.0
        } else {
            self.plan_hits as f64 / total as f64
        }
    }
}

/// What one [`BatchExecutor::execute_batch`] call did.
#[derive(Clone, Debug)]
pub struct BatchReport {
    /// Products executed.
    pub products: usize,
    /// Wall time of the whole pipelined batch.
    pub wall_s: f64,
    /// Summed plan (grouping + symbolic) wall seconds for the batch's
    /// *unique* structures — runs on the planner thread, overlapped
    /// with fills; repeated structures share one plan.
    pub plan_s: f64,
    /// Summed numeric-fill wall seconds (calling thread).
    pub fill_s: f64,
    /// Table-I bins of every product packed onto the stream model with
    /// LPT. **Weights are intermediate-product counts, not ms** — the
    /// `Schedule`'s `*_ms` fields are in IP units here, so only relative
    /// quantities (assignment, utilization, makespan ratios) are
    /// meaningful; do not compare against simulated `sim_ms`.
    pub streams: Schedule,
}

impl BatchReport {
    /// Overlap win: serial plan+fill seconds divided by the pipelined
    /// wall seconds (> 1 when planning hid behind fills).
    pub fn overlap_speedup(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 1.0;
        }
        (self.plan_s + self.fill_s) / self.wall_s
    }
}

/// Plans once, fills many: the coordinator-level entry point for
/// iterative and batched SpGEMM (MCL expansion chains, GNN epochs,
/// benchmark sweeps).
///
/// # Example
///
/// ```
/// use spgemm_aia::coordinator::batch::BatchExecutor;
/// use spgemm_aia::sparse::Csr;
///
/// let a = Csr::identity(16);
/// let mut ex = BatchExecutor::new(4);
///
/// // Batched: planning of product k+1 overlaps the fill of product k;
/// // the repeated structure here is planned once and shared.
/// let out = ex.execute_batch(&[(&a, &a), (&a, &a)]);
/// assert_eq!(out.len(), 2);
/// assert_eq!(out[0], out[1]);
///
/// // Cached: a repeated structure reuses its plan (numeric phase only).
/// let c1 = ex.multiply_cached(&a, &a);
/// let c2 = ex.multiply_cached(&a, &a);
/// assert_eq!(c1, c2);
/// assert!(ex.stats.plan_hits >= 1);
/// ```
pub struct BatchExecutor {
    /// Streams the bin-level [`Schedule`] packs onto (paper §III-C
    /// launches each row group on its own stream).
    pub n_streams: usize,
    /// Lifetime counters.
    pub stats: BatchStats,
    /// Report for the most recent [`BatchExecutor::execute_batch`] call.
    pub last_batch: Option<BatchReport>,
    cache: HashMap<u64, Arc<PlannedProduct>>,
}

impl BatchExecutor {
    pub fn new(n_streams: usize) -> BatchExecutor {
        assert!(n_streams > 0, "need at least one stream");
        BatchExecutor {
            n_streams,
            stats: BatchStats::default(),
            last_batch: None,
            cache: HashMap::new(),
        }
    }

    /// Execute a batch of products with the symbolic/numeric pipeline:
    /// a planner thread produces [`PlannedProduct`]s in input order
    /// (running a bounded number of products ahead) while the calling
    /// thread runs the numeric fills. Repeated structures — within the
    /// batch or already in the plan cache — share one plan, and plans
    /// built here seed the cache for later
    /// [`BatchExecutor::multiply_cached`] calls. Outputs are returned in
    /// input order and are bit-identical to per-pair
    /// [`crate::spgemm::hash::multiply`] calls.
    pub fn execute_batch(&mut self, pairs: &[(&Csr, &Csr)]) -> Vec<Csr> {
        let t_batch = Instant::now();
        let mut plan_s = 0.0;
        let mut fill_s = 0.0;
        let mut reused = 0usize;
        let mut fresh_plans: Vec<Arc<PlannedProduct>> = Vec::new();
        let mut jobs: Vec<Job> = Vec::new();
        let mut out: Vec<Option<Csr>> = Vec::new();
        out.resize_with(pairs.len(), || None);
        // Read-only view of the cache for the planner thread (Arc
        // clones — the plans themselves are shared, not copied).
        let snapshot = self.cache.clone();
        std::thread::scope(|s| {
            let (tx, rx) = mpsc::sync_channel::<(usize, Arc<PlannedProduct>, bool)>(PIPELINE_DEPTH);
            s.spawn(move || {
                // Plans built earlier in this batch, keyed like the cache.
                let mut built: HashMap<u64, Arc<PlannedProduct>> = HashMap::new();
                for (i, &(a, b)) in pairs.iter().enumerate() {
                    let (ah, bh) = (a.structure_hash(), b.structure_hash());
                    let key = pair_key_from_hashes(ah, bh);
                    let existing = built
                        .get(&key)
                        .or_else(|| snapshot.get(&key))
                        .filter(|p| p.matches_fingerprint((a.n_rows, a.n_cols), (b.n_rows, b.n_cols), ah, bh))
                        .cloned();
                    let (p, fresh) = match existing {
                        Some(p) => (p, false),
                        None => {
                            let p = Arc::new(PlannedProduct::plan(a, b));
                            built.insert(key, Arc::clone(&p));
                            (p, true)
                        }
                    };
                    if tx.send((i, p, fresh)).is_err() {
                        return; // receiver unwound — stop planning
                    }
                }
            });
            for (i, p, fresh) in rx {
                if fresh {
                    plan_s += p.plan_times.total_s();
                    fresh_plans.push(Arc::clone(&p));
                } else {
                    reused += 1;
                }
                for (g, &w) in p.group_work().iter().enumerate() {
                    if w > 0 {
                        jobs.push(Job { id: format!("p{i}/group{g}"), ms: w as f64 });
                    }
                }
                let (a, b) = pairs[i];
                // Unchecked: the planner thread validated (or freshly
                // built) the plan against these operands' fingerprints.
                let (c, secs) = p.fill_unchecked_timed(a, b);
                fill_s += secs;
                out[i] = Some(c);
            }
        });
        let fresh_count = fresh_plans.len();
        self.stats.plans_built += fresh_count;
        self.stats.plan_misses += fresh_count;
        self.stats.plan_hits += reused;
        self.stats.fills += pairs.len();
        self.stats.plan_s += plan_s;
        self.stats.fill_s += fill_s;
        for p in fresh_plans {
            self.cache_insert(p.key(), p);
        }
        self.last_batch = Some(BatchReport {
            products: pairs.len(),
            wall_s: t_batch.elapsed().as_secs_f64(),
            plan_s,
            fill_s,
            streams: schedule_lpt(&jobs, self.n_streams),
        });
        out.into_iter().map(|c| c.expect("pipeline produced every product")).collect()
    }

    /// Multiply through the plan cache: reuse the cached plan when the
    /// operands' structure is unchanged (numeric phase only), replan and
    /// cache otherwise. Hit/miss counts land in [`BatchStats`]. Each
    /// operand is hashed exactly once per call (key and validation share
    /// the fingerprints).
    pub fn multiply_cached(&mut self, a: &Csr, b: &Csr) -> Csr {
        let (ah, bh) = (a.structure_hash(), b.structure_hash());
        let key = pair_key_from_hashes(ah, bh);
        if let Some(p) = self.cache.get(&key) {
            if p.matches_fingerprint((a.n_rows, a.n_cols), (b.n_rows, b.n_cols), ah, bh) {
                self.stats.plan_hits += 1;
                let (c, secs) = p.fill_unchecked_timed(a, b);
                self.stats.fills += 1;
                self.stats.fill_s += secs;
                return c;
            }
        }
        self.stats.plan_misses += 1;
        let p = PlannedProduct::plan(a, b);
        self.stats.plans_built += 1;
        self.stats.plan_s += p.plan_times.total_s();
        let (c, secs) = p.fill_unchecked_timed(a, b);
        self.stats.fills += 1;
        self.stats.fill_s += secs;
        self.cache_insert(key, Arc::new(p));
        c
    }

    /// Insert a plan, evicting an arbitrary entry at the cap.
    fn cache_insert(&mut self, key: u64, p: Arc<PlannedProduct>) {
        if self.cache.len() >= CACHE_CAP && !self.cache.contains_key(&key) {
            let evict = self.cache.keys().next().copied();
            if let Some(k) = evict {
                self.cache.remove(&k);
            }
        }
        self.cache.insert(key, p);
    }

    /// Number of plans currently cached.
    pub fn cached_plans(&self) -> usize {
        self.cache.len()
    }

    /// Drop every cached plan (e.g. after a sparsification event that
    /// invalidates the structures the cache was keyed on).
    pub fn invalidate(&mut self) {
        self.cache.clear();
    }

    /// Model the §III-C stream assignment for one planned product: one
    /// job per non-empty Table-I bin, weighted by the bin's summed
    /// intermediate products, LPT-packed onto [`BatchExecutor::n_streams`]
    /// streams.
    ///
    /// The weights are **IP counts, not milliseconds** — the returned
    /// [`Schedule`]'s `*_ms` fields are in IP units, so use it for
    /// relative comparisons (assignment, utilization, makespan ratios)
    /// only, never against simulated `sim_ms` values.
    pub fn stream_schedule(&self, p: &PlannedProduct) -> Schedule {
        let jobs: Vec<Job> = p
            .group_work()
            .iter()
            .enumerate()
            .filter(|&(_, &w)| w > 0)
            .map(|(g, &w)| Job { id: format!("group{g}"), ms: w as f64 })
            .collect();
        schedule_lpt(&jobs, self.n_streams)
    }

    /// Export counters into a [`Metrics`] registry under `batch.*`.
    pub fn export_metrics(&self, m: &mut Metrics) {
        m.inc("batch.plans_built", self.stats.plans_built as u64);
        m.inc("batch.fills", self.stats.fills as u64);
        m.inc("batch.plan_hits", self.stats.plan_hits as u64);
        m.inc("batch.plan_misses", self.stats.plan_misses as u64);
        m.add_time("batch.plan", self.stats.plan_s);
        m.add_time("batch.fill", self.stats.fill_s);
        m.gauge("batch.plan_hit_rate", self.stats.hit_rate());
        if let Some(r) = &self.last_batch {
            m.gauge("batch.last.overlap_speedup", r.overlap_speedup());
            m.gauge("batch.last.stream_utilization", r.streams.utilization());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spgemm::hash;
    use crate::util::Pcg32;

    fn random_square(seed: u64, n: usize, per_row: usize) -> Csr {
        let mut rng = Pcg32::seeded(seed);
        crate::gen::rmat(n, n * per_row, crate::gen::RmatParams::uniform(), &mut rng)
    }

    #[test]
    fn batch_matches_serial_multiplies() {
        let a = random_square(1, 128, 4);
        let b = random_square(2, 128, 5);
        let pairs = [(&a, &a), (&a, &b), (&b, &b)];
        let mut ex = BatchExecutor::new(4);
        let out = ex.execute_batch(&pairs);
        assert_eq!(out.len(), 3);
        for (i, &(x, y)) in pairs.iter().enumerate() {
            assert_eq!(out[i], hash::multiply(x, y), "batch product {i} must equal cold multiply");
        }
        let r = ex.last_batch.as_ref().expect("batch report recorded");
        assert_eq!(r.products, 3);
        assert!(r.wall_s > 0.0 && r.plan_s > 0.0 && r.fill_s > 0.0);
        assert!(r.streams.makespan_ms > 0.0);
        // Three distinct structures: every product had to plan.
        assert_eq!(ex.stats.plans_built, 3);
        assert_eq!(ex.stats.fills, 3);
        assert_eq!(ex.stats.plan_hits, 0);
    }

    #[test]
    fn batch_dedupes_repeated_structures_and_seeds_cache() {
        let a = random_square(8, 96, 4);
        let mut ex = BatchExecutor::new(2);
        let out = ex.execute_batch(&[(&a, &a), (&a, &a), (&a, &a)]);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], out[2]);
        assert_eq!(ex.stats.plans_built, 1, "identical structures must share one plan");
        assert_eq!((ex.stats.plan_hits, ex.stats.plan_misses), (2, 1));
        // The batch's plan seeded the cache: a following cached multiply
        // hits, and a second identical batch plans nothing.
        ex.multiply_cached(&a, &a);
        assert_eq!(ex.stats.plan_hits, 3);
        assert_eq!(ex.cached_plans(), 1);
        ex.execute_batch(&[(&a, &a)]);
        assert_eq!(ex.stats.plans_built, 1);
        assert_eq!(ex.stats.plan_hits, 4);
    }

    #[test]
    fn empty_batch_is_fine() {
        let mut ex = BatchExecutor::new(2);
        assert!(ex.execute_batch(&[]).is_empty());
        assert_eq!(ex.last_batch.as_ref().unwrap().products, 0);
    }

    #[test]
    fn cache_hits_on_repeated_structure() {
        let a = random_square(3, 96, 4);
        let mut ex = BatchExecutor::new(2);
        let c1 = ex.multiply_cached(&a, &a);
        assert_eq!((ex.stats.plan_hits, ex.stats.plan_misses), (0, 1));
        // Same structure, new values: must hit and still be exact.
        let mut a2 = a.clone();
        a2.map_values(|v| v * 0.5 + 1.0);
        let c2 = ex.multiply_cached(&a2, &a2);
        assert_eq!((ex.stats.plan_hits, ex.stats.plan_misses), (1, 1));
        assert_eq!(c2, hash::multiply(&a2, &a2));
        assert_ne!(c1, c2);
        assert!(ex.stats.hit_rate() > 0.4 && ex.stats.hit_rate() < 0.6);
        assert_eq!(ex.cached_plans(), 1);
        ex.invalidate();
        assert_eq!(ex.cached_plans(), 0);
    }

    #[test]
    fn cache_replans_on_structure_change() {
        let a = random_square(4, 96, 4);
        let b = random_square(5, 96, 5);
        let mut ex = BatchExecutor::new(2);
        ex.multiply_cached(&a, &a);
        let c = ex.multiply_cached(&b, &b);
        assert_eq!(ex.stats.plan_misses, 2);
        assert_eq!(c, hash::multiply(&b, &b));
    }

    #[test]
    fn stream_schedule_covers_nonempty_bins() {
        let a = random_square(6, 256, 6);
        let p = crate::spgemm::hash::PlannedProduct::plan(&a, &a);
        let ex = BatchExecutor::new(4);
        let s = ex.stream_schedule(&p);
        let nonempty = p.group_work().iter().filter(|&&w| w > 0).count();
        assert_eq!(s.assignment.len(), nonempty);
        assert!(s.makespan_ms > 0.0);
        let total: f64 = p.group_work().iter().map(|&w| w as f64).sum();
        assert!((s.serial_ms - total).abs() < 1e-9);
    }

    #[test]
    fn metrics_export() {
        let a = random_square(7, 96, 4);
        let mut ex = BatchExecutor::new(2);
        ex.multiply_cached(&a, &a); // miss, plan cached
        ex.multiply_cached(&a, &a); // hit
        ex.execute_batch(&[(&a, &a)]); // hit via the cache snapshot
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        assert_eq!(m.counter("batch.plan_hits"), 2);
        assert_eq!(m.counter("batch.plan_misses"), 1);
        assert_eq!(m.counter("batch.plans_built"), 1);
        assert_eq!(m.counter("batch.fills"), 3);
        assert!(m.timer_total("batch.fill") >= 0.0);
    }
}
