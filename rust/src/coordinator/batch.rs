//! Batched plan-reuse execution (ROADMAP "Batched multi-matrix
//! execution" + "True intra-product phase overlap").
//!
//! [`BatchExecutor`] drives the engine's plan-reuse layer
//! ([`PlannedProduct`]) at application scope:
//!
//! - **Per-bin pipelined batches** — [`BatchExecutor::execute_batch`]
//!   plans a set of products on a dedicated planner thread and streams
//!   the numeric fills on the calling thread. The pipeline's unit is
//!   the **numeric bin** (one Table-I group × one accumulator kind, see
//!   [`crate::spgemm::hash::NumericBin`]), not the whole product: as
//!   soon as a product's symbolic counts land, the planner emits one
//!   completion event per bin over the bounded channel — in LPT order,
//!   heaviest first, the same packing [`schedule_lpt`] uses — and the
//!   consumer fills each bin on arrival. Symbolic analysis of product
//!   *k+1* therefore overlaps the *individual bin fills* of product
//!   *k*, not just its whole numeric phase (the host-side analogue of
//!   per-stream kernel launches instead of a per-phase barrier). The
//!   bins of every product are also packed onto the coordinator's
//!   stream model with [`schedule_lpt`], which lets the group-3
//!   (global-table, AIA-heavy) and SPA (streaming) bins co-schedule
//!   with the PWPR bins; the resulting [`Schedule`] lands in the
//!   [`BatchReport`] along with the per-accumulator-kind fill split.
//! - **Plan caching** — plans are keyed by the operands' structure
//!   fingerprints and shared through a tiered
//!   [`crate::spgemm::hash::planstore::TieredStore`] (memory tier, plus
//!   the versioned on-disk tier when a plan-cache directory is
//!   configured): [`BatchExecutor::multiply_cached`] reuses across
//!   calls, and [`BatchExecutor::execute_batch`] dedupes repeated
//!   structures within a batch, consults the store, and seeds it with
//!   the plans it builds — so iterative callers (MCL expansions, GNN
//!   epochs) pay the symbolic phase only when a structure is genuinely
//!   new *to the store*, which with a disk tier includes structures
//!   planned by earlier processes. Hit/miss counts live in
//!   [`BatchStats`] and are **per unique structure hash**: a plan
//!   shared across several slots of one batch counts one hit (or one
//!   miss) plus [`BatchStats::batch_shared`] shares, never one hit per
//!   slot. Disk-tier traffic is split out
//!   ([`BatchStats::disk_hits`] / [`BatchStats::disk_corrupt`]), and a
//!   corrupt or stale plan file always degrades to a silent replan.
//!
//! Both paths produce output bit-identical to a cold
//! [`crate::spgemm::hash::multiply`].
//!
//! Note on units: the stream-model job weights are **intermediate-product
//! counts**, not milliseconds — see [`BatchExecutor::stream_schedule`].

use super::metrics::Metrics;
use super::scheduler::{schedule_lpt, Job, Schedule};
use crate::spgemm::hash::planstore::{GetOutcome, StoreStats};
use crate::spgemm::hash::{multiply_estimated_cfg, EstimateParams, Mask, PlannerPolicy};
use crate::spgemm::hash::{numeric_bin_into, EngineConfig, PlanFingerprint, PlanStore, PlannedProduct, TieredStore};
use crate::sparse::Csr;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// How many pipeline events (plan completions + per-bin completions)
/// the channel buffers. Worst case for plan memory is one-bin
/// products (Plan+Bin pairs): 4 events ≈ 2 buffered plans plus the
/// one being built — the same peak the old whole-product depth of 2
/// allowed, now at bin granularity so multi-bin products overlap
/// per bin instead of per phase.
const PIPELINE_DEPTH: usize = 4;

/// Counters accumulated across a [`BatchExecutor`]'s lifetime.
///
/// Hit/miss counters are **per unique structure hash**: within one
/// batch, the first slot with a given structure scores the hit (plan
/// found in the cache) or the miss (plan had to be built); every
/// further slot sharing that plan scores [`BatchStats::batch_shared`]
/// instead. (The executor used to count a hit per *slot*, double-counting
/// deduped `Arc` plans — pinned by
/// `plan_cache_stats_count_per_unique_structure`.)
#[derive(Clone, Debug, Default)]
pub struct BatchStats {
    /// Symbolic plans built (structures that were new).
    pub plans_built: usize,
    /// Numeric fills executed (one per product).
    pub fills: usize,
    /// Unique structures served by an already-cached plan.
    pub plan_hits: usize,
    /// Unique structures that had to build a plan.
    pub plan_misses: usize,
    /// Batch slots that shared a plan with an earlier slot of the same
    /// batch (in-batch dedup — neither a hit nor a miss).
    pub batch_shared: usize,
    /// Unique structures served by the plan store's *disk* tier: a plan
    /// written by an earlier process (or an earlier store on the same
    /// directory), loaded, fingerprint-validated, and promoted to the
    /// memory tier. Counted separately from [`BatchStats::plan_hits`]
    /// so the cross-process win is visible.
    pub disk_hits: usize,
    /// Plan files that failed to load (bad magic/version/checksum or
    /// truncated) — each degraded to a silent miss + replan.
    pub disk_corrupt: usize,
    /// Per-bin completion events filled by the batch pipeline.
    pub bins_filled: usize,
    /// Products served by dirty-row delta patching
    /// ([`crate::spgemm::hash::delta_patch`]): the previous same-shape
    /// plan was patched in place instead of a full replan. Neither a
    /// hit nor a miss — excluded from [`BatchStats::hit_rate`] on both
    /// sides (regression-pinned).
    pub delta_patches: usize,
    /// Rows whose symbolic phase re-ran across all delta patches (the
    /// dirty sets' total size).
    pub delta_rows: usize,
    /// Wall seconds spent building delta patches (subset of
    /// [`BatchStats::plan_s`]).
    pub delta_plan_s: f64,
    /// Cold one-shot products served by the speculative estimated
    /// planner ([`PlannerPolicy::Estimated`]/`Auto` through
    /// [`BatchExecutor::multiply_cached_policy`]). Speculative plans
    /// are used once and never persisted, so these are neither hits
    /// nor misses — excluded from [`BatchStats::hit_rate`] on both
    /// sides, like delta patches.
    pub estimated_plans: usize,
    /// Rows the speculative numeric phase grew-and-retried after
    /// detecting an underestimate (summed
    /// [`crate::spgemm::hash::EstimateReport::fallback_rows`]).
    pub fallback_rows: usize,
    /// Wall seconds spent sampling + building speculative plans
    /// (subset of [`BatchStats::plan_s`]).
    pub estimate_s: f64,
    /// Wall seconds spent resolving plans: grouping + symbolic for
    /// fresh structures, disk load + validation for disk hits, plus the
    /// fingerprint validation (an O(nnz) structure scan on first touch,
    /// a memo read after) that hits and in-batch shares still pay —
    /// omitting the latter overstated the reported reuse saving
    /// (regression-pinned by
    /// `plan_resolution_time_is_accounted_for_cache_hits`).
    pub plan_s: f64,
    /// Wall seconds spent in numeric fills.
    pub fill_s: f64,
}

impl BatchStats {
    /// Fraction of products served without replanning (memory- and
    /// disk-tier hits both count — neither ran the symbolic phase).
    /// Delta-patched products are excluded from numerator *and*
    /// denominator: they re-ran the symbolic phase over their dirty
    /// rows only, so folding them into either side would skew the rate.
    pub fn hit_rate(&self) -> f64 {
        let hits = self.plan_hits + self.disk_hits;
        let total = hits + self.plan_misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

/// Where a plan-reuse lookup resolved one product's plan.
///
/// Was a private detail of [`BatchExecutor::execute_batch`]; the serve
/// daemon reports it per request (`"plan":"fresh|shared|mem|disk"`), so
/// it is public with a stable wire label.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanSource {
    /// Structure new to the store: the symbolic phase ran.
    Fresh,
    /// Resolved earlier in the same batch (in-batch dedup).
    Shared,
    /// Memory-tier hit.
    Mem,
    /// Disk-tier hit (plan from an earlier process, validated).
    Disk,
    /// Store miss patched from the previous same-shape plan: the
    /// symbolic phase re-ran over the dirty rows only
    /// ([`crate::spgemm::hash::delta_patch`]).
    Delta,
    /// Fully-cold one-shot product planned speculatively from sampled
    /// estimates ([`crate::spgemm::hash::multiply_estimated`]): no
    /// exact symbolic phase ran, underestimated rows grew-and-retried,
    /// and the plan was never admitted to the store.
    Estimated,
}

impl PlanSource {
    /// Stable lowercase label — what the serve line protocol emits.
    pub fn label(self) -> &'static str {
        match self {
            PlanSource::Fresh => "fresh",
            PlanSource::Shared => "shared",
            PlanSource::Mem => "mem",
            PlanSource::Disk => "disk",
            PlanSource::Delta => "delta",
            PlanSource::Estimated => "estimated",
        }
    }

    /// True when the symbolic phase was skipped entirely (verbatim
    /// reuse). A delta patch is *not* a hit: it re-ran the symbolic
    /// phase, just only over its dirty rows. An estimated plan is not
    /// a hit either: nothing was reused — the plan was guessed.
    pub fn is_hit(self) -> bool {
        !matches!(self, PlanSource::Fresh | PlanSource::Delta | PlanSource::Estimated)
    }
}

/// Per-call trace of one [`BatchExecutor::multiply_cached_traced`]:
/// where the plan came from and what the call cost. The serve daemon's
/// per-request accounting (and its CI smoke assertion that a second
/// identical product pays zero symbolic seconds) rides on this.
#[derive(Clone, Copy, Debug)]
pub struct CachedMultiply {
    /// Where the plan was resolved (never [`PlanSource::Shared`] here —
    /// sharing is a batch concept).
    pub source: PlanSource,
    /// Seconds resolving the plan: fingerprint + store lookup, plus
    /// grouping + symbolic analysis when the structure was new.
    pub plan_s: f64,
    /// Seconds in the numeric fill.
    pub fill_s: f64,
    /// Symbolic-phase seconds *this call* paid: the freshly built
    /// plan's symbolic wall time on a miss, exactly `0.0` on any hit —
    /// the quantity plan reuse exists to zero out.
    pub symbolic_s: f64,
    /// Output nonzeros.
    pub nnz: usize,
}

/// What one [`BatchExecutor::execute_batch`] call did.
#[derive(Clone, Debug)]
pub struct BatchReport {
    /// Products executed.
    pub products: usize,
    /// Per-bin completion events dispatched (and filled) — the
    /// pipeline's work units; ≥ `products` whenever any product has
    /// more than one non-empty bin.
    pub bins: usize,
    /// Wall time of the whole pipelined batch.
    pub wall_s: f64,
    /// Planner-thread wall seconds resolving the batch's plans:
    /// grouping + symbolic analysis for *unique* fresh structures, disk
    /// load + validation for disk-tier hits, plus fingerprint
    /// validation for every product (cache hits and in-batch shares are
    /// not free, though the memoized structure hashes make repeat
    /// validation a cell read) — overlapped with fills.
    pub plan_s: f64,
    /// Plan-side symbolic seconds split by counting kernel, indexed by
    /// `SymbolicKind::index()` (trivial, hash, bitmap) — summed over
    /// the batch's freshly built plans, the per-kind *symbolic*
    /// counterpart of `fill_kind_s`.
    pub symbolic_kind_s: [f64; 3],
    /// Summed numeric-fill wall seconds (calling thread).
    pub fill_s: f64,
    /// `fill_s` split by accumulator kind, indexed by
    /// `AccumKind::index()` (copy, hash, SPA).
    pub fill_kind_s: [f64; 3],
    /// Unique structures of this batch served by the plan store's disk
    /// tier (symbolic phase skipped across a process boundary).
    pub disk_hits: usize,
    /// Unique structures of this batch served by dirty-row delta
    /// patching instead of a full replan.
    pub delta_patches: usize,
    /// Rows whose symbolic phase re-ran across this batch's delta
    /// patches (total dirty-set size; compare against `products` ×
    /// rows to see the replanning saved).
    pub delta_rows: usize,
    /// Planner seconds spent building delta patches (subset of
    /// `plan_s`).
    pub delta_plan_s: f64,
    /// Symbolic seconds the delta patches paid over their dirty rows —
    /// the incremental counterpart of the fresh plans'
    /// `symbolic_kind_s` total, so full-vs-delta symbolic cost is
    /// directly comparable per batch.
    pub symbolic_delta_s: f64,
    /// Per-kind numeric bins of every product packed onto the stream
    /// model with LPT. **Weights are intermediate-product counts, not
    /// ms** — the `Schedule`'s `*_ms` fields are in IP units here, so
    /// only relative quantities (assignment, utilization, makespan
    /// ratios) are meaningful; do not compare against simulated
    /// `sim_ms`.
    pub streams: Schedule,
}

impl BatchReport {
    /// Overlap win: serial plan+fill seconds divided by the pipelined
    /// wall seconds (> 1 when planning hid behind fills).
    pub fn overlap_speedup(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 1.0;
        }
        (self.plan_s + self.fill_s) / self.wall_s
    }
}

/// Plans once, fills many: the coordinator-level entry point for
/// iterative and batched SpGEMM (MCL expansion chains, GNN epochs,
/// benchmark sweeps).
///
/// # Example
///
/// ```
/// use spgemm_aia::coordinator::batch::BatchExecutor;
/// use spgemm_aia::sparse::Csr;
///
/// let a = Csr::identity(16);
/// let mut ex = BatchExecutor::new(4);
///
/// // Batched: planning of product k+1 overlaps the fill of product k;
/// // the repeated structure here is planned once and shared.
/// let out = ex.execute_batch(&[(&a, &a), (&a, &a)]);
/// assert_eq!(out.len(), 2);
/// assert_eq!(out[0], out[1]);
///
/// // Cached: a repeated structure reuses its plan (numeric phase only).
/// let c1 = ex.multiply_cached(&a, &a);
/// let c2 = ex.multiply_cached(&a, &a);
/// assert_eq!(c1, c2);
/// assert!(ex.stats.plan_hits >= 1);
/// ```
pub struct BatchExecutor {
    /// Streams the bin-level [`Schedule`] packs onto (paper §III-C
    /// launches each row group on its own stream).
    pub n_streams: usize,
    /// Lifetime counters.
    pub stats: BatchStats,
    /// Report for the most recent [`BatchExecutor::execute_batch`] call.
    pub last_batch: Option<BatchReport>,
    /// Planner policy [`BatchExecutor::multiply_cached`]-style one-shot
    /// calls run under (batched and iterative products always plan
    /// exactly — their plans are reused, so speculation has nothing to
    /// win). Defaults to the process-wide policy (`--planner` /
    /// `SPGEMM_AIA_PLANNER`, see [`EngineConfig::default`]).
    pub planner: PlannerPolicy,
    store: TieredStore,
    /// Most recently resolved plan key per operand-shape quadruple —
    /// the delta planner's predecessor index: on a store miss, the
    /// previous same-shape plan (fetched via
    /// [`TieredStore::peek_key`]) is the dirty-row patch baseline.
    recent_by_shape: HashMap<[usize; 4], u64>,
}

impl BatchExecutor {
    /// Executor over the process-default plan store
    /// ([`TieredStore::process_default`]): memory tier always, plus the
    /// on-disk tier when `--plan-cache` / `SPGEMM_AIA_PLAN_CACHE`
    /// configured a directory.
    pub fn new(n_streams: usize) -> BatchExecutor {
        BatchExecutor::with_store(n_streams, TieredStore::process_default())
    }

    /// Executor over an explicit plan store (tests, benches, and the
    /// repro harness pin their cache directories with this).
    pub fn with_store(n_streams: usize, store: TieredStore) -> BatchExecutor {
        assert!(n_streams > 0, "need at least one stream");
        BatchExecutor {
            n_streams,
            stats: BatchStats::default(),
            last_batch: None,
            planner: EngineConfig::default().planner,
            store,
            recent_by_shape: HashMap::new(),
        }
    }

    /// Execute a batch of products with the per-bin symbolic/numeric
    /// pipeline: a planner thread produces [`PlannedProduct`]s in input
    /// order and, the moment a product's symbolic counts land, emits
    /// one completion event per numeric bin (heaviest first — the LPT
    /// issue order) over the bounded channel; the calling thread fills
    /// each bin as its event arrives. The planner runs a bounded number
    /// of *bins* ahead, so symbolic analysis of product *k+1* overlaps
    /// the individual bin fills of product *k*.
    ///
    /// Repeated structures — within the batch or already in the plan
    /// cache — share one plan (counted per unique structure hash, see
    /// [`BatchStats`]), and plans built here seed the cache for later
    /// [`BatchExecutor::multiply_cached`] calls. Outputs are returned in
    /// input order and are bit-identical to per-pair
    /// [`crate::spgemm::hash::multiply`] calls.
    pub fn execute_batch(&mut self, pairs: &[(&Csr, &Csr)]) -> Vec<Csr> {
        /// Pipeline events, in channel order per product: one `Plan`
        /// (symbolic counts landed), then one `Bin` per numeric bin.
        enum PipeEvent {
            Plan {
                slot: usize,
                plan: Arc<PlannedProduct>,
                source: PlanSource,
                /// A plan file for this fingerprint was unreadable
                /// (degraded to whatever `source` says happened next).
                corrupt: bool,
                /// A plan file parsed but carried a foreign fingerprint.
                stale: bool,
                /// Dirty rows the delta patch replanned (0 unless
                /// `source` is [`PlanSource::Delta`]).
                delta_rows: usize,
                resolve_s: f64,
            },
            Bin { slot: usize, bin: usize },
        }
        /// A product mid-fill on the consumer side.
        struct SlotState {
            plan: Arc<PlannedProduct>,
            col: Vec<u32>,
            val: Vec<f64>,
            bins_done: usize,
        }

        let t_batch = Instant::now();
        let mut plan_s = 0.0;
        let mut symbolic_kind_s = [0f64; 3];
        let mut fill_s = 0.0;
        let mut fill_kind_s = [0f64; 3];
        let mut bins_filled = 0usize;
        let mut hits = 0usize;
        let mut disk_hits = 0usize;
        let mut corrupts = 0usize;
        let mut stales = 0usize;
        let mut shared = 0usize;
        let mut deltas = 0usize;
        let mut delta_rows_total = 0usize;
        let mut delta_plan_s = 0.0;
        let mut symbolic_delta_s = 0.0;
        let mut fresh_plans: Vec<Arc<PlannedProduct>> = Vec::new();
        let mut delta_plans: Vec<Arc<PlannedProduct>> = Vec::new();
        let mut disk_loaded: Vec<Arc<PlannedProduct>> = Vec::new();
        let mut jobs: Vec<Job> = Vec::new();
        let mut out: Vec<Option<Csr>> = Vec::new();
        out.resize_with(pairs.len(), || None);
        let mut slots: Vec<Option<SlotState>> = Vec::new();
        slots.resize_with(pairs.len(), || None);
        // Read-only view of the tiered store for the planner thread:
        // `Arc` clones of the memory tier plus a stateless disk handle —
        // disk load + validation happen on the planner thread, where
        // they overlap the numeric fills like any other plan resolution.
        let snapshot = self.store.snapshot();
        // The planner thread's copy of the predecessor index — updated
        // as it resolves, so later slots of this batch can delta off
        // earlier ones; the consumer folds the updates back afterwards.
        let mut recent = self.recent_by_shape.clone();
        std::thread::scope(|s| {
            let (tx, rx) = mpsc::sync_channel::<PipeEvent>(PIPELINE_DEPTH);
            let recent = &mut recent;
            s.spawn(move || {
                // Plans resolved earlier in this batch, keyed like the
                // store — in-batch shares are neither hits nor misses.
                let mut resolved: HashMap<u64, Arc<PlannedProduct>> = HashMap::new();
                for (i, &(a, b)) in pairs.iter().enumerate() {
                    let t_resolve = Instant::now();
                    // The operands' structure hashes are memoized, so
                    // fingerprinting repeated structures is a cell read.
                    let fp = PlanFingerprint::of(a, b);
                    let key = fp.key();
                    let shape = [a.n_rows, a.n_cols, b.n_rows, b.n_cols];
                    let (mut corrupt, mut stale) = (false, false);
                    let mut delta_rows = 0usize;
                    let (p, source) = if let Some(p) = resolved.get(&key).filter(|p| fp.matches(p)) {
                        (Arc::clone(p), PlanSource::Shared)
                    } else {
                        match snapshot.lookup(&fp) {
                            (Some(p), GetOutcome::MemHit) => {
                                resolved.insert(key, Arc::clone(&p));
                                (p, PlanSource::Mem)
                            }
                            (Some(p), _) => {
                                resolved.insert(key, Arc::clone(&p));
                                (p, PlanSource::Disk)
                            }
                            (None, outcome) => {
                                if let GetOutcome::Miss { corrupt: c, stale: st } = outcome {
                                    corrupt = c;
                                    stale = st;
                                }
                                let cfg = EngineConfig::default();
                                // Store miss: before a full replan, try
                                // patching the previous same-shape plan's
                                // dirty rows (the baseline may live in
                                // this batch's `resolved` set or in the
                                // store snapshot).
                                let base = recent
                                    .get(&shape)
                                    .and_then(|k| resolved.get(k).map(Arc::clone).or_else(|| snapshot.peek_key(*k)));
                                let patched = base.as_deref().and_then(|base| {
                                    match crate::spgemm::hash::delta_patch(base, a, b, &cfg) {
                                        crate::spgemm::hash::DeltaOutcome::Patched(dp) => Some(dp),
                                        crate::spgemm::hash::DeltaOutcome::Rebuild(_) => None,
                                    }
                                });
                                match patched {
                                    Some(dp) => {
                                        delta_rows = dp.dirty_rows;
                                        let p = Arc::new(dp.plan);
                                        resolved.insert(key, Arc::clone(&p));
                                        (p, PlanSource::Delta)
                                    }
                                    None => {
                                        // Fingerprints double as the plan's
                                        // validation hashes — each operand is
                                        // structure-scanned at most once.
                                        let p = Arc::new(PlannedProduct::plan_cfg_hashed(a, b, &cfg, fp.a_hash, fp.b_hash));
                                        resolved.insert(key, Arc::clone(&p));
                                        (p, PlanSource::Fresh)
                                    }
                                }
                            }
                        }
                    };
                    recent.insert(shape, key);
                    let resolve_s = t_resolve.elapsed().as_secs_f64();
                    // Symbolic counts are in: dispatch the product's bins
                    // heaviest-first (LPT issue order) behind the plan event.
                    let bins = &p.symbolic_plan().bins;
                    let mut order: Vec<usize> = (0..bins.len()).collect();
                    order.sort_by(|&x, &y| bins[y].weight.cmp(&bins[x].weight).then(x.cmp(&y)));
                    let ev = PipeEvent::Plan { slot: i, plan: Arc::clone(&p), source, corrupt, stale, delta_rows, resolve_s };
                    if tx.send(ev).is_err() {
                        return; // receiver unwound — stop planning
                    }
                    for bin in order {
                        if tx.send(PipeEvent::Bin { slot: i, bin }).is_err() {
                            return;
                        }
                    }
                }
            });
            for ev in rx {
                match ev {
                    PipeEvent::Plan { slot, plan, source, corrupt, stale, delta_rows, resolve_s } => {
                        // Planner-thread cost of this product: fingerprint
                        // resolution (and for disk hits the load+validate)
                        // plus, for fresh structures, the grouping/symbolic
                        // analysis. Counted for hits and in-batch shares
                        // too — validation is real work, and reporting it
                        // as 0 overstated the reuse win.
                        plan_s += resolve_s;
                        if corrupt {
                            corrupts += 1;
                        }
                        if stale {
                            stales += 1;
                        }
                        match source {
                            PlanSource::Fresh => {
                                for (k, v) in symbolic_kind_s.iter_mut().zip(plan.plan_times.symbolic_kind_s) {
                                    *k += v;
                                }
                                fresh_plans.push(Arc::clone(&plan));
                            }
                            PlanSource::Mem => hits += 1,
                            PlanSource::Disk => {
                                disk_hits += 1;
                                disk_loaded.push(Arc::clone(&plan));
                            }
                            PlanSource::Shared => shared += 1,
                            PlanSource::Delta => {
                                deltas += 1;
                                delta_rows_total += delta_rows;
                                delta_plan_s += plan.plan_times.total_s();
                                symbolic_delta_s += plan.plan_times.symbolic_s;
                                delta_plans.push(Arc::clone(&plan));
                            }
                        }
                        for bin in &plan.symbolic_plan().bins {
                            jobs.push(Job { id: format!("p{slot}/{}", bin.label()), ms: bin.weight as f64 });
                        }
                        let nnz = plan.nnz();
                        let st = SlotState { col: vec![0u32; nnz], val: vec![0f64; nnz], plan, bins_done: 0 };
                        if st.plan.symbolic_plan().bins.is_empty() {
                            // Nothing to fill (empty output): finish now.
                            let (a, b) = pairs[slot];
                            let rpt = st.plan.symbolic_plan().rpt.clone();
                            out[slot] = Some(Csr::new_unchecked(a.n_rows, b.n_cols, rpt, st.col, st.val));
                        } else {
                            slots[slot] = Some(st);
                        }
                    }
                    PipeEvent::Bin { slot, bin } => {
                        let (a, b) = pairs[slot];
                        let st = slots[slot].as_mut().expect("plan event precedes its bin events");
                        let kind_idx = st.plan.symbolic_plan().bins[bin].kind.index();
                        let n_bins = st.plan.symbolic_plan().bins.len();
                        let t0 = Instant::now();
                        // Unchecked per-bin fill: the planner thread
                        // validated (or freshly built) the plan against
                        // these operands' fingerprints.
                        numeric_bin_into(a, b, st.plan.symbolic_plan(), bin, &mut st.col, &mut st.val);
                        let secs = t0.elapsed().as_secs_f64();
                        fill_s += secs;
                        fill_kind_s[kind_idx] += secs;
                        bins_filled += 1;
                        st.bins_done += 1;
                        if st.bins_done == n_bins {
                            let st = slots[slot].take().expect("slot is mid-fill");
                            let rpt = st.plan.symbolic_plan().rpt.clone();
                            out[slot] = Some(Csr::new_unchecked(a.n_rows, b.n_cols, rpt, st.col, st.val));
                        }
                    }
                }
            }
        });
        let fresh_count = fresh_plans.len();
        self.stats.plans_built += fresh_count;
        self.stats.plan_misses += fresh_count;
        self.stats.plan_hits += hits;
        self.stats.disk_hits += disk_hits;
        self.stats.disk_corrupt += corrupts;
        self.stats.batch_shared += shared;
        self.stats.delta_patches += deltas;
        self.stats.delta_rows += delta_rows_total;
        self.stats.delta_plan_s += delta_plan_s;
        self.stats.fills += pairs.len();
        self.stats.bins_filled += bins_filled;
        self.stats.plan_s += plan_s;
        self.stats.fill_s += fill_s;
        // The planner's predecessor index survives into the next call.
        self.recent_by_shape = recent;
        // The planner thread resolved against a snapshot: fold what it
        // observed into the store's own counters, promote disk-loaded
        // plans into the memory tier, and write fresh plans through to
        // both tiers. Delta patches tally as `delta_patches`, neither
        // hit nor miss.
        self.store.tally(&StoreStats {
            mem_hits: hits as u64,
            disk_hits: disk_hits as u64,
            misses: fresh_count as u64,
            corrupt: corrupts as u64,
            stale: stales as u64,
            delta_patches: deltas as u64,
            ..StoreStats::default()
        });
        for p in disk_loaded {
            self.store.admit(p, false);
        }
        for p in fresh_plans {
            self.store.admit(p, true);
        }
        for p in delta_plans {
            self.store.admit(p, true);
        }
        self.last_batch = Some(BatchReport {
            products: pairs.len(),
            bins: bins_filled,
            wall_s: t_batch.elapsed().as_secs_f64(),
            plan_s,
            symbolic_kind_s,
            fill_s,
            fill_kind_s,
            disk_hits,
            delta_patches: deltas,
            delta_rows: delta_rows_total,
            delta_plan_s,
            symbolic_delta_s,
            streams: schedule_lpt(&jobs, self.n_streams),
        });
        out.into_iter().map(|c| c.expect("pipeline produced every product")).collect()
    }

    /// Multiply through the tiered plan store: reuse a stored plan when
    /// the operands' structure is unchanged (numeric phase only —
    /// memory tier first, then the validated disk tier), replan and
    /// store otherwise. Hit/miss counts land in [`BatchStats`]
    /// (disk-tier hits under [`BatchStats::disk_hits`]). The operands'
    /// structure hashes are memoized, so fingerprinting costs one scan
    /// per matrix lifetime, not one per call.
    pub fn multiply_cached(&mut self, a: &Csr, b: &Csr) -> Csr {
        self.multiply_cached_traced(a, b).0
    }

    /// [`BatchExecutor::multiply_cached`] plus a per-call
    /// [`CachedMultiply`] trace: plan source, resolve/fill seconds, and
    /// the symbolic seconds this call actually paid (0 on any hit).
    /// Runs under this executor's [`BatchExecutor::planner`] policy.
    pub fn multiply_cached_traced(&mut self, a: &Csr, b: &Csr) -> (Csr, CachedMultiply) {
        self.multiply_cached_policy(a, b, self.planner)
    }

    /// [`BatchExecutor::multiply_cached_traced`] under an explicit
    /// [`PlannerPolicy`] (the serve daemon threads each request's
    /// policy through here).
    ///
    /// Speculation is *store-first*: under `Estimated`/`Auto` the
    /// tiered store and the dirty-row delta baseline are probed exactly
    /// as in exact mode — a hit fills from the stored exact plan, a
    /// same-shape drift delta-patches — and only a *fully-cold*
    /// structure runs the sampled estimator
    /// ([`crate::spgemm::hash::multiply_estimated`]). The speculative
    /// plan is used once and thrown away: it is never admitted to the
    /// store ([`StoreStats::stores`] does not move), so no later
    /// process can mistake its guessed row sizes for exact symbolic
    /// output.
    pub fn multiply_cached_policy(&mut self, a: &Csr, b: &Csr, policy: PlannerPolicy) -> (Csr, CachedMultiply) {
        self.multiply_cached_inner(a, b, None, policy)
    }

    /// Masked multiply through the tiered plan store: `C = mask ⊙
    /// (A·B)`, planned with the masked symbolic kernels so the plan's
    /// exact sizes (and the numeric fill) never materialize a
    /// mask-rejected entry. The mask's structure hash joins the
    /// [`PlanFingerprint`], so masked plans cache, persist, and
    /// delta-patch like any other — distinct from the unmasked plan of
    /// the same operands. Masked products never speculate: a guessed
    /// global compression ratio says nothing about an arbitrary mask,
    /// so `Estimated`/`Auto` degrade to the exact planner here.
    pub fn multiply_cached_masked(&mut self, a: &Csr, b: &Csr, mask: &Mask) -> Csr {
        self.multiply_cached_masked_policy(a, b, mask, self.planner).0
    }

    /// [`BatchExecutor::multiply_cached_masked`] under an explicit
    /// policy, with the per-call [`CachedMultiply`] trace.
    pub fn multiply_cached_masked_policy(
        &mut self,
        a: &Csr,
        b: &Csr,
        mask: &Mask,
        policy: PlannerPolicy,
    ) -> (Csr, CachedMultiply) {
        assert_eq!(mask.shape(), (a.n_rows, b.n_cols), "mask shape must equal the output shape");
        self.multiply_cached_inner(a, b, Some(mask), policy)
    }

    fn multiply_cached_inner(
        &mut self,
        a: &Csr,
        b: &Csr,
        mask: Option<&Mask>,
        policy: PlannerPolicy,
    ) -> (Csr, CachedMultiply) {
        let t_resolve = Instant::now();
        let fp = match mask {
            None => PlanFingerprint::of(a, b),
            Some(m) => PlanFingerprint::of_masked(a, b, m),
        };
        let shape = [a.n_rows, a.n_cols, b.n_rows, b.n_cols];
        let (found, outcome) = self.store.get_traced(&fp);
        if let Some(p) = found {
            let source = match outcome {
                GetOutcome::DiskHit => {
                    self.stats.disk_hits += 1;
                    PlanSource::Disk
                }
                _ => {
                    self.stats.plan_hits += 1;
                    PlanSource::Mem
                }
            };
            self.recent_by_shape.insert(shape, fp.key());
            // Hits still pay fingerprint validation (and disk hits the
            // load): count it so reuse is never reported as entirely
            // free.
            let plan_s = t_resolve.elapsed().as_secs_f64();
            self.stats.plan_s += plan_s;
            let (c, ft) = p.fill_unchecked_timed(a, b);
            self.stats.fills += 1;
            self.stats.fill_s += ft.numeric_s;
            let trace = CachedMultiply { source, plan_s, fill_s: ft.numeric_s, symbolic_s: 0.0, nnz: c.nnz() };
            return (c, trace);
        }
        if let GetOutcome::Miss { corrupt: true, .. } = outcome {
            self.stats.disk_corrupt += 1;
        }
        let cfg = EngineConfig { mask: mask.cloned(), ..EngineConfig::default() };
        // Store miss: before a full replan, try patching the previous
        // same-shape plan's dirty rows (dynamic-graph drift — e.g. a
        // re-registered handle with a mutated matrix).
        let patched = self
            .recent_by_shape
            .get(&shape)
            .and_then(|k| self.store.peek_key(*k))
            .and_then(|base| match crate::spgemm::hash::delta_patch(&base, a, b, &cfg) {
                crate::spgemm::hash::DeltaOutcome::Patched(dp) => Some(dp),
                crate::spgemm::hash::DeltaOutcome::Rebuild(_) => None,
            });
        if patched.is_none() && policy.speculates() && mask.is_none() {
            // Fully cold and one-shot: speculate. Sampling + the
            // fallback-guarded numeric fill happen in one call; the
            // plan never reaches the store, and `recent_by_shape` is
            // left alone — a guessed plan is no delta baseline.
            let (c, rep) = multiply_estimated_cfg(a, b, &cfg, &EstimateParams::default());
            let plan_s = t_resolve.elapsed().as_secs_f64() - rep.numeric_s;
            self.stats.estimated_plans += 1;
            self.stats.estimate_s += rep.estimate_s;
            self.stats.fallback_rows += rep.fallback_rows;
            self.stats.plan_s += plan_s;
            self.stats.fills += 1;
            self.stats.fill_s += rep.numeric_s;
            let trace = CachedMultiply {
                source: PlanSource::Estimated,
                plan_s,
                fill_s: rep.numeric_s,
                symbolic_s: 0.0,
                nnz: c.nnz(),
            };
            return (c, trace);
        }
        let (p, source, symbolic_s) = match patched {
            Some(dp) => {
                let p = Arc::new(dp.plan);
                self.stats.delta_patches += 1;
                self.stats.delta_rows += dp.dirty_rows;
                self.stats.delta_plan_s += p.plan_times.total_s();
                // The lookup above scored a miss, but a patched product
                // is neither a hit nor a miss — reclassify it.
                self.store.note_delta_patch();
                let symbolic_s = p.plan_times.symbolic_s;
                (p, PlanSource::Delta, symbolic_s)
            }
            None => {
                self.stats.plan_misses += 1;
                // Key fingerprints double as the plan's validation
                // hashes, and the miss counts the same resolve wall
                // time the hit path does, so the two paths stay
                // comparable.
                let p = Arc::new(PlannedProduct::plan_cfg_hashed(a, b, &cfg, fp.a_hash, fp.b_hash));
                self.stats.plans_built += 1;
                let symbolic_s = p.plan_times.symbolic_s;
                (p, PlanSource::Fresh, symbolic_s)
            }
        };
        self.recent_by_shape.insert(shape, fp.key());
        let plan_s = t_resolve.elapsed().as_secs_f64();
        self.stats.plan_s += plan_s;
        let (c, ft) = p.fill_unchecked_timed(a, b);
        self.stats.fills += 1;
        self.stats.fill_s += ft.numeric_s;
        self.store.put(p);
        let trace = CachedMultiply { source, plan_s, fill_s: ft.numeric_s, symbolic_s, nnz: c.nnz() };
        (c, trace)
    }

    /// Number of plans currently in the store's memory tier.
    pub fn cached_plans(&self) -> usize {
        self.store.len()
    }

    /// The plan store's own counters (per-tier hit/miss/evict/corrupt).
    pub fn store_stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// The disk tier's cache directory, if one is attached.
    pub fn plan_cache_dir(&self) -> Option<std::path::PathBuf> {
        self.store.disk_dir()
    }

    /// A shared handle to this executor's plan store — [`TieredStore`]
    /// clones share tiers and counters, so a serve session (or another
    /// executor) built from this handle reuses the same cache.
    pub fn store(&self) -> TieredStore {
        self.store.clone()
    }

    /// Drop the store's memory tier (e.g. after a sparsification event
    /// that invalidates the structures it was keyed on). Disk files are
    /// left in place — they are fingerprint-validated on every load, so
    /// a stale file costs a read, never a wrong result.
    pub fn invalidate(&mut self) {
        self.store.clear();
    }

    /// Model the §III-C stream assignment for one planned product: one
    /// job per numeric bin (Table-I group × accumulator kind), weighted
    /// by the bin's summed intermediate products, LPT-packed onto
    /// [`BatchExecutor::n_streams`] streams — the same order
    /// [`BatchExecutor::execute_batch`] dispatches per-bin completion
    /// events in.
    ///
    /// The weights are **IP counts, not milliseconds** — the returned
    /// [`Schedule`]'s `*_ms` fields are in IP units, so use it for
    /// relative comparisons (assignment, utilization, makespan ratios)
    /// only, never against simulated `sim_ms` values.
    pub fn stream_schedule(&self, p: &PlannedProduct) -> Schedule {
        let jobs: Vec<Job> = p
            .symbolic_plan()
            .bins
            .iter()
            .map(|bin| Job { id: bin.label(), ms: bin.weight as f64 })
            .collect();
        schedule_lpt(&jobs, self.n_streams)
    }

    /// Export counters into a [`Metrics`] registry under `batch.*`
    /// (executor-level) and `batch.store.*` (plan-store tiers).
    pub fn export_metrics(&self, m: &mut Metrics) {
        m.inc("batch.plans_built", self.stats.plans_built as u64);
        m.inc("batch.fills", self.stats.fills as u64);
        m.inc("batch.plan_hits", self.stats.plan_hits as u64);
        m.inc("batch.plan_misses", self.stats.plan_misses as u64);
        m.inc("batch.disk_hits", self.stats.disk_hits as u64);
        m.inc("batch.disk_corrupt", self.stats.disk_corrupt as u64);
        m.inc("batch.batch_shared", self.stats.batch_shared as u64);
        m.inc("batch.delta_patches", self.stats.delta_patches as u64);
        m.inc("batch.delta_rows", self.stats.delta_rows as u64);
        m.gauge("batch.delta_plan_s", self.stats.delta_plan_s);
        m.inc("batch.estimated_plans", self.stats.estimated_plans as u64);
        m.inc("batch.fallback_rows", self.stats.fallback_rows as u64);
        m.gauge("batch.estimate_s", self.stats.estimate_s);
        m.inc("batch.bins_filled", self.stats.bins_filled as u64);
        m.observe_store_stats("batch.store", &self.store.stats());
        m.add_time("batch.plan", self.stats.plan_s);
        m.add_time("batch.fill", self.stats.fill_s);
        m.gauge("batch.plan_hit_rate", self.stats.hit_rate());
        if let Some(r) = &self.last_batch {
            m.gauge("batch.last.overlap_speedup", r.overlap_speedup());
            m.gauge("batch.last.stream_utilization", r.streams.utilization());
            m.gauge("batch.last.bins", r.bins as f64);
            // Gauges, not timers: this is a snapshot of the last batch,
            // and repeated exports must not accumulate it.
            m.gauge("batch.last.fill_copy_s", r.fill_kind_s[0]);
            m.gauge("batch.last.fill_hash_s", r.fill_kind_s[1]);
            m.gauge("batch.last.fill_spa_s", r.fill_kind_s[2]);
            m.gauge("batch.last.symbolic_trivial_s", r.symbolic_kind_s[0]);
            m.gauge("batch.last.symbolic_hash_s", r.symbolic_kind_s[1]);
            m.gauge("batch.last.symbolic_bitmap_s", r.symbolic_kind_s[2]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spgemm::hash;
    use crate::util::Pcg32;

    fn random_square(seed: u64, n: usize, per_row: usize) -> Csr {
        let mut rng = Pcg32::seeded(seed);
        crate::gen::rmat(n, n * per_row, crate::gen::RmatParams::uniform(), &mut rng)
    }

    /// Executor pinned to a memory-only store: these tests assert exact
    /// hit/miss counts, which a `SPGEMM_AIA_PLAN_CACHE` env var leaking
    /// in from the developer's shell (→ process-default disk tier,
    /// warm from a previous `cargo test`) would turn stateful. Disk-tier
    /// behavior is covered by `tests/plan_store.rs` with pinned dirs.
    fn mem_executor(n_streams: usize) -> BatchExecutor {
        BatchExecutor::with_store(n_streams, TieredStore::mem_only())
    }

    #[test]
    fn batch_matches_serial_multiplies() {
        let a = random_square(1, 128, 4);
        let b = random_square(2, 128, 5);
        let pairs = [(&a, &a), (&a, &b), (&b, &b)];
        let mut ex = mem_executor(4);
        let out = ex.execute_batch(&pairs);
        assert_eq!(out.len(), 3);
        for (i, &(x, y)) in pairs.iter().enumerate() {
            assert_eq!(out[i], hash::multiply(x, y), "batch product {i} must equal cold multiply");
        }
        let r = ex.last_batch.as_ref().expect("batch report recorded");
        assert_eq!(r.products, 3);
        assert!(r.bins >= r.products, "every product fills at least one bin");
        assert!(r.wall_s > 0.0 && r.plan_s > 0.0 && r.fill_s > 0.0);
        let kind_total: f64 = r.fill_kind_s.iter().sum();
        assert!((kind_total - r.fill_s).abs() < 1e-9, "per-kind split must sum to fill_s");
        let sym_total: f64 = r.symbolic_kind_s.iter().sum();
        assert!(sym_total > 0.0, "per-kernel symbolic split must be recorded for fresh plans");
        assert!(sym_total <= r.plan_s + 1e-9, "symbolic kernel seconds are part of the plan seconds");
        assert!(r.streams.makespan_ms > 0.0);
        // Three distinct structures: every product had to plan.
        assert_eq!(ex.stats.plans_built, 3);
        assert_eq!(ex.stats.fills, 3);
        assert_eq!(ex.stats.plan_hits, 0);
        assert_eq!(ex.stats.bins_filled, r.bins);
    }

    #[test]
    fn batch_dedupes_repeated_structures_and_seeds_cache() {
        let a = random_square(8, 96, 4);
        let mut ex = mem_executor(2);
        let out = ex.execute_batch(&[(&a, &a), (&a, &a), (&a, &a)]);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], out[2]);
        assert_eq!(ex.stats.plans_built, 1, "identical structures must share one plan");
        // One unique structure, freshly built: one miss, zero hits —
        // the two deduped slots are in-batch shares, not cache hits.
        assert_eq!((ex.stats.plan_hits, ex.stats.plan_misses), (0, 1));
        assert_eq!(ex.stats.batch_shared, 2);
        // The batch's plan seeded the cache: a following cached multiply
        // hits, and a second identical batch plans nothing.
        ex.multiply_cached(&a, &a);
        assert_eq!(ex.stats.plan_hits, 1);
        assert_eq!(ex.cached_plans(), 1);
        ex.execute_batch(&[(&a, &a)]);
        assert_eq!(ex.stats.plans_built, 1);
        assert_eq!(ex.stats.plan_hits, 2);
    }

    /// Regression: plan-cache hit stats used to be counted per *slot*,
    /// so a deduped `Arc` plan shared across slots of one batch scored
    /// a hit per slot. They are counted per unique structure hash now.
    #[test]
    fn plan_cache_stats_count_per_unique_structure() {
        let a = random_square(11, 96, 4);
        let b = random_square(12, 96, 4);
        let mut ex = mem_executor(2);
        // Seed the cache with a's plan.
        ex.multiply_cached(&a, &a);
        assert_eq!((ex.stats.plan_hits, ex.stats.plan_misses), (0, 1));
        // 3 slots share the cached a-plan, 2 slots share a fresh b-plan:
        // exactly one hit (a, cached) and one miss (b, built) — not 3
        // hits — plus three in-batch shares.
        let out = ex.execute_batch(&[(&a, &a), (&a, &a), (&b, &b), (&a, &a), (&b, &b)]);
        assert_eq!(out.len(), 5);
        assert_eq!(out[0], out[3]);
        assert_eq!(out[2], out[4]);
        assert_eq!(
            (ex.stats.plan_hits, ex.stats.plan_misses, ex.stats.batch_shared),
            (1, 2, 3),
            "stats must count per unique structure hash, not per slot"
        );
        assert_eq!(ex.stats.plans_built, 2);
        assert_eq!(ex.stats.fills, 6);
        // Outputs are still exact under all the sharing.
        assert_eq!(out[1], hash::multiply(&a, &a));
        assert_eq!(out[4], hash::multiply(&b, &b));
    }

    /// Regression: `BatchReport.plan_s`/`BatchStats.plan_s` counted 0
    /// planner seconds for products served from the plan cache, even
    /// though the planner thread fingerprint-validates every hit (an
    /// O(nnz) structure scan on first touch, a memo read after) — so
    /// the reported plan-reuse saving was overstated.
    #[test]
    fn plan_resolution_time_is_accounted_for_cache_hits() {
        // Large enough that two structure hashes take measurable time.
        let a = random_square(21, 4096, 8);
        let mut ex = mem_executor(2);
        ex.execute_batch(&[(&a, &a)]);
        let cold = ex.last_batch.as_ref().unwrap().plan_s;
        assert!(cold > 0.0);
        let stats_plan_s = ex.stats.plan_s;
        // Second batch: both slots resolve from the cache (one hit, one
        // in-batch share) — no plans built, but resolution is not free.
        ex.execute_batch(&[(&a, &a), (&a, &a)]);
        assert_eq!(ex.stats.plans_built, 1, "second batch must be served from the cache");
        let r = ex.last_batch.as_ref().unwrap();
        assert!(r.plan_s > 0.0, "cache-hit products still cost fingerprint validation");
        assert!(ex.stats.plan_s > stats_plan_s, "lifetime plan seconds must include validation");
        assert_eq!(r.symbolic_kind_s, [0.0; 3], "no fresh plan → no new symbolic kernel seconds");
        // The cached `multiply_cached` hit path counts validation too.
        let before = ex.stats.plan_s;
        ex.multiply_cached(&a, &a);
        assert!(ex.stats.plan_s > before);
    }

    #[test]
    fn masked_cached_multiply_caches_separately_and_never_speculates() {
        let a = random_square(51, 128, 4);
        let mask = Mask::from_structure(&a);
        let oracle = mask.filter(&hash::multiply(&a, &a));
        let mut ex = mem_executor(2);
        ex.multiply_cached(&a, &a);
        // The masked product is a distinct store identity: a miss that
        // plans fresh, then a memory hit — alongside the unmasked plan.
        let (c1, t1) = ex.multiply_cached_masked_policy(&a, &a, &mask, PlannerPolicy::Exact);
        assert_eq!(c1, oracle, "masked cached multiply must equal the filtered oracle");
        assert_eq!(t1.source, PlanSource::Fresh);
        let (c2, t2) = ex.multiply_cached_masked_policy(&a, &a, &mask, PlannerPolicy::Exact);
        assert_eq!(c2, oracle);
        assert_eq!(t2.source, PlanSource::Mem);
        assert_eq!(ex.cached_plans(), 2, "masked and unmasked plans coexist under distinct keys");
        // Same-mask structural drift rides the dirty-row delta path.
        let a2 = hash::mutate_row_fraction(&a, 0.02, 9);
        let (c3, t3) = ex.multiply_cached_masked_policy(&a2, &a, &mask, PlannerPolicy::Exact);
        assert_eq!(c3, mask.filter(&hash::multiply(&a2, &a)));
        assert_eq!(t3.source, PlanSource::Delta, "masked drift must delta-patch under the same mask");
        // An estimating policy degrades to the exact planner under a
        // mask — a fresh masked structure must never speculate.
        let b = random_square(52, 128, 4);
        let bmask = Mask::from_structure(&b);
        let (c4, t4) = ex.multiply_cached_masked_policy(&b, &b, &bmask, PlannerPolicy::Estimated);
        assert_eq!(c4, bmask.filter(&hash::multiply(&b, &b)));
        assert_eq!(t4.source, PlanSource::Fresh, "masked products never speculate");
        assert_eq!(ex.stats.estimated_plans, 0);
    }

    #[test]
    fn empty_batch_is_fine() {
        let mut ex = mem_executor(2);
        assert!(ex.execute_batch(&[]).is_empty());
        assert_eq!(ex.last_batch.as_ref().unwrap().products, 0);
    }

    #[test]
    fn cache_hits_on_repeated_structure() {
        let a = random_square(3, 96, 4);
        let mut ex = mem_executor(2);
        let c1 = ex.multiply_cached(&a, &a);
        assert_eq!((ex.stats.plan_hits, ex.stats.plan_misses), (0, 1));
        // Same structure, new values: must hit and still be exact.
        let mut a2 = a.clone();
        a2.map_values(|v| v * 0.5 + 1.0);
        let c2 = ex.multiply_cached(&a2, &a2);
        assert_eq!((ex.stats.plan_hits, ex.stats.plan_misses), (1, 1));
        assert_eq!(c2, hash::multiply(&a2, &a2));
        assert_ne!(c1, c2);
        assert!(ex.stats.hit_rate() > 0.4 && ex.stats.hit_rate() < 0.6);
        assert_eq!(ex.cached_plans(), 1);
        ex.invalidate();
        assert_eq!(ex.cached_plans(), 0);
    }

    #[test]
    fn traced_multiply_reports_source_and_symbolic_cost() {
        let a = random_square(9, 96, 4);
        let mut ex = mem_executor(2);
        let (c1, t1) = ex.multiply_cached_traced(&a, &a);
        assert_eq!(t1.source, PlanSource::Fresh);
        assert!(!t1.source.is_hit());
        assert_eq!(t1.source.label(), "fresh");
        assert!(t1.symbolic_s > 0.0, "a fresh plan pays the symbolic phase");
        assert_eq!(t1.nnz, c1.nnz());
        let (c2, t2) = ex.multiply_cached_traced(&a, &a);
        assert_eq!(t2.source, PlanSource::Mem);
        assert!(t2.source.is_hit());
        assert_eq!(t2.source.label(), "mem");
        assert_eq!(t2.symbolic_s, 0.0, "a plan hit pays zero symbolic seconds");
        assert_eq!(c1, c2, "hit and miss paths are bit-identical");
        assert_eq!(t1.nnz, t2.nnz);
    }

    /// A mutated same-shape structure routes through the dirty-row
    /// delta planner on both entry points — `multiply_cached_traced`
    /// (the serve path) and `execute_batch` (the planner thread) — with
    /// exact output, `"delta"` as the wire label, and counters that
    /// keep delta patches out of the hit rate on both sides.
    #[test]
    fn cached_and_batched_paths_delta_patch_mutated_structures() {
        let a = random_square(31, 192, 5);
        let mut ex = mem_executor(2);
        let (c0, t0) = ex.multiply_cached_traced(&a, &a);
        assert_eq!(t0.source, PlanSource::Fresh);
        // Serve path: small drift → delta.
        let a2 = hash::mutate_row_fraction(&a, 0.02, 41);
        let (c2, t2) = ex.multiply_cached_traced(&a2, &a2);
        assert_eq!(t2.source, PlanSource::Delta);
        assert_eq!(t2.source.label(), "delta");
        assert!(!t2.source.is_hit(), "a delta patch re-ran symbolic work, it is not a hit");
        assert_eq!(c2, hash::multiply(&a2, &a2), "patched fill must be exact");
        assert_ne!(c0, c2);
        assert_eq!((ex.stats.plan_hits, ex.stats.plan_misses, ex.stats.delta_patches), (0, 1, 1));
        assert!(ex.stats.delta_rows > 0 && ex.stats.delta_rows < a.n_rows);
        // The store agrees: the patch is neither a hit nor a miss there.
        let ss = ex.store_stats();
        assert_eq!(ss.delta_patches, 1);
        assert_eq!((ss.hits(), ss.misses), (0, 1), "the patched lookup's miss was reclassified");
        // hit_rate excludes the delta on both sides of the fraction.
        assert_eq!(ex.stats.hit_rate(), 0.0);
        // Batch path: a further drift delta-patches on the planner thread.
        let a3 = hash::mutate_row_fraction(&a2, 0.02, 42);
        let out = ex.execute_batch(&[(&a3, &a3)]);
        assert_eq!(out[0], hash::multiply(&a3, &a3));
        let r = ex.last_batch.as_ref().unwrap();
        assert_eq!((r.delta_patches, ex.stats.delta_patches), (1, 2));
        assert!(r.delta_rows > 0 && r.delta_plan_s > 0.0);
        assert!(r.symbolic_delta_s <= r.delta_plan_s + 1e-9);
        // The patched plan chains off the patched predecessor.
        assert_eq!(ex.store_stats().delta_patches, 2);
        // Counters export.
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        assert_eq!(m.counter("batch.delta_patches"), 2);
        assert_eq!(m.counter("batch.delta_rows"), ex.stats.delta_rows as u64);
    }

    /// Policy boundaries: `Estimated` speculates only on a fully-cold
    /// structure — a stored exact plan still wins — and the speculative
    /// plan is never admitted to the store (neither tier, zero
    /// `stores`), with output bit-identical to the exact engine.
    #[test]
    fn estimated_policy_is_store_first_and_never_persists() {
        let a = random_square(17, 128, 4);
        let mut ex = mem_executor(2);
        let (c1, t1) = ex.multiply_cached_policy(&a, &a, PlannerPolicy::Estimated);
        assert_eq!(t1.source, PlanSource::Estimated);
        assert_eq!(t1.source.label(), "estimated");
        assert!(!t1.source.is_hit(), "a guessed plan reused nothing — not a hit");
        assert_eq!(t1.symbolic_s, 0.0, "no exact symbolic phase ran");
        assert_eq!(c1, hash::multiply(&a, &a), "speculative output must be bit-identical");
        assert_eq!(ex.cached_plans(), 0, "speculative plans must never reach the store");
        assert_eq!(ex.store_stats().stores, 0, "no store write from a speculative plan");
        assert_eq!(ex.stats.estimated_plans, 1);
        assert_eq!((ex.stats.plan_hits, ex.stats.plan_misses), (0, 0), "neither a hit nor a miss");
        assert_eq!(ex.stats.hit_rate(), 0.0);
        // Warm the store with the exact plan: the same policy now rides
        // the hit instead of re-estimating (store-first).
        ex.multiply_cached(&a, &a);
        let stores_after_exact = ex.store_stats().stores;
        let (c3, t3) = ex.multiply_cached_policy(&a, &a, PlannerPolicy::Estimated);
        assert_eq!(t3.source, PlanSource::Mem, "a store hit must beat speculation");
        assert_eq!(c3, c1);
        assert_eq!(ex.stats.estimated_plans, 1, "no second estimate once the plan is cached");
        assert_eq!(ex.store_stats().stores, stores_after_exact);
        // Batched products always plan exactly, whatever the executor's
        // default policy says.
        ex.invalidate();
        ex.planner = PlannerPolicy::Estimated;
        let b = random_square(18, 128, 4);
        let out = ex.execute_batch(&[(&b, &b)]);
        assert_eq!(out[0], hash::multiply(&b, &b));
        assert_eq!(ex.stats.estimated_plans, 1, "execute_batch must stay exact");
        assert_eq!(ex.cached_plans(), 1, "the batch's exact plan is stored as usual");
    }

    /// `Auto` behaves like `Estimated` on cold one-shot calls and like
    /// `Exact` wherever an exact plan is reusable (store hit, delta
    /// baseline).
    #[test]
    fn auto_policy_speculates_only_on_cold_one_shot_calls() {
        let a = random_square(19, 160, 5);
        let mut ex = mem_executor(2);
        let (_, t1) = ex.multiply_cached_policy(&a, &a, PlannerPolicy::Auto);
        assert_eq!(t1.source, PlanSource::Estimated, "cold one-shot under auto speculates");
        // Seed an exact plan, then drift the structure: the delta
        // baseline must win over re-estimating.
        ex.multiply_cached(&a, &a);
        let a2 = hash::mutate_row_fraction(&a, 0.02, 43);
        let (c2, t2) = ex.multiply_cached_policy(&a2, &a2, PlannerPolicy::Auto);
        assert_eq!(t2.source, PlanSource::Delta, "a delta baseline must beat speculation");
        assert_eq!(c2, hash::multiply(&a2, &a2));
        // Exact policy never speculates, cold or not.
        let b = random_square(20, 160, 5);
        let (_, t3) = ex.multiply_cached_policy(&b, &b, PlannerPolicy::Exact);
        assert_eq!(t3.source, PlanSource::Fresh);
        assert_eq!(ex.stats.estimated_plans, 1);
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        assert_eq!(m.counter("batch.estimated_plans"), 1);
    }

    #[test]
    fn cache_replans_on_structure_change() {
        let a = random_square(4, 96, 4);
        let b = random_square(5, 96, 5);
        let mut ex = mem_executor(2);
        ex.multiply_cached(&a, &a);
        let c = ex.multiply_cached(&b, &b);
        assert_eq!(ex.stats.plan_misses, 2);
        assert_eq!(c, hash::multiply(&b, &b));
    }

    #[test]
    fn stream_schedule_covers_all_numeric_bins() {
        let a = random_square(6, 256, 6);
        let p = crate::spgemm::hash::PlannedProduct::plan(&a, &a);
        let ex = mem_executor(4);
        let s = ex.stream_schedule(&p);
        assert_eq!(s.assignment.len(), p.symbolic_plan().bins.len());
        assert!(s.makespan_ms > 0.0);
        // Bin weights partition the total IP (empty-output rows have
        // zero IP), so the serial time equals the group-work total.
        let total: f64 = p.group_work().iter().map(|&w| w as f64).sum();
        assert!((s.serial_ms - total).abs() < 1e-9);
    }

    #[test]
    fn metrics_export() {
        let a = random_square(7, 96, 4);
        let mut ex = mem_executor(2);
        ex.multiply_cached(&a, &a); // miss, plan cached
        ex.multiply_cached(&a, &a); // hit
        ex.execute_batch(&[(&a, &a)]); // hit via the cache snapshot
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        assert_eq!(m.counter("batch.plan_hits"), 2);
        assert_eq!(m.counter("batch.plan_misses"), 1);
        assert_eq!(m.counter("batch.plans_built"), 1);
        assert_eq!(m.counter("batch.fills"), 3);
        assert!(m.counter("batch.bins_filled") >= 1);
        assert_eq!(m.counter("batch.batch_shared"), 0);
        assert!(m.timer_total("batch.fill") >= 0.0);
    }
}
