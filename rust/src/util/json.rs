//! Tiny JSON emitter *and parser* (offline build has no `serde_json`).
//!
//! Writing covers what the metrics registry and the repro harness
//! need: objects, arrays, numbers, strings, with correct escaping.
//! Reading ([`Json::parse`]) exists for the serve daemon's line
//! protocol (`serve/protocol.rs`): a recursive-descent parser over the
//! same [`Json`] tree, hardened for untrusted socket input — depth
//! capped, every error a message instead of a panic. It is lenient
//! where strict JSON is pedantic (leading zeros in numbers parse), and
//! strict where it matters (strings must be valid escapes, input must
//! be one complete document with nothing trailing).

use crate::util::error::{anyhow, bail, ensure, Result};
use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Nesting cap for [`Json::parse`] — socket input must not be able to
/// overflow the stack with `[[[[…`.
const MAX_PARSE_DEPTH: usize = 64;

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Parse one complete JSON document (see the module docs for the
    /// leniency/strictness contract). Objects keep their key order;
    /// duplicate keys are kept as-is and [`Json::get`] returns the
    /// first.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        let v = p.value()?;
        p.ws();
        ensure!(p.i == p.b.len(), "trailing characters after the document at byte {}", p.i);
        Ok(v)
    }

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Integer view: `Int` directly, or a `Num` that is exactly
    /// integral and in range (protocol fields like seeds arrive as
    /// whatever the client's emitter produced).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(x) if x.fract() == 0.0 && *x >= i64::MIN as f64 && *x <= i64::MAX as f64 => Some(*x as i64),
            _ => None,
        }
    }

    /// Non-negative integer view (see [`Json::as_i64`]).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    /// Numeric view: `Num` directly, `Int` widened.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Insert/overwrite a key on an object (panics on non-objects —
    /// programmer error).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(&mut self, val: Json) -> &mut Self {
        match self {
            Json::Arr(xs) => xs.push(val),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn render_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    x.write(out, indent + 1, pretty);
                }
                if pretty && !xs.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !pairs.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Recursive-descent state for [`Json::parse`]: a byte cursor over the
/// input (always a valid `&str`, so multi-byte scalars can be copied by
/// slicing at their boundaries) plus the current nesting depth.
struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.b.get(self.i) {
            None => bail!("unexpected end of input"),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(&c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(&c) => bail!("unexpected character {:?} at byte {}", c as char, self.i),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        ensure!(self.b[self.i..].starts_with(word.as_bytes()), "bad literal at byte {}", self.i);
        self.i += word.len();
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.b.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        while self.b.get(self.i).is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        let mut float = false;
        if self.b.get(self.i) == Some(&b'.') {
            float = true;
            self.i += 1;
            while self.b.get(self.i).is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.b.get(self.i), Some(b'e' | b'E')) {
            float = true;
            self.i += 1;
            if matches!(self.b.get(self.i), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while self.b.get(self.i).is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("number chars are ASCII");
        if !float {
            // Exact integers stay `Int` (a u64 handle in a 53-bit f64
            // would silently round); overflow falls through to f64.
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        match text.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => bail!("bad number {text:?} at byte {start}"),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.i += 1; // opening quote
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => out.push(self.unicode_escape()?),
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(&c) if c < 0x20 => bail!("raw control character in string at byte {}", self.i),
                Some(&c) => {
                    // Copy one UTF-8 scalar; the input is a valid &str,
                    // so slicing at the leading byte's length is safe.
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    ensure!(self.i + len <= self.b.len(), "truncated UTF-8 scalar");
                    out.push_str(std::str::from_utf8(&self.b[self.i..self.i + len]).expect("input is valid UTF-8"));
                    self.i += len;
                }
            }
        }
    }

    /// `\uXXXX` (cursor on the `u`), including surrogate pairs; leaves
    /// the cursor on the last consumed hex digit.
    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        if (0xdc00..0xe000).contains(&hi) {
            bail!("unpaired low surrogate \\u{hi:04x}");
        }
        if !(0xd800..0xdc00).contains(&hi) {
            return char::from_u32(hi).ok_or_else(|| anyhow!("invalid scalar \\u{hi:04x}"));
        }
        // High surrogate: the low half must follow immediately.
        ensure!(
            self.b.get(self.i + 1) == Some(&b'\\') && self.b.get(self.i + 2) == Some(&b'u'),
            "unpaired high surrogate \\u{hi:04x}"
        );
        self.i += 2; // onto the second 'u'
        let lo = self.hex4()?;
        ensure!((0xdc00..0xe000).contains(&lo), "invalid low surrogate \\u{lo:04x}");
        let c = 0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00);
        char::from_u32(c).ok_or_else(|| anyhow!("invalid surrogate pair"))
    }

    /// Four hex digits after the `u` the cursor sits on; advances the
    /// cursor onto the last digit.
    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for k in 1..=4 {
            let d = self
                .b
                .get(self.i + k)
                .and_then(|c| (*c as char).to_digit(16))
                .ok_or_else(|| anyhow!("bad \\u escape at byte {}", self.i))?;
            v = v * 16 + d;
        }
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json> {
        self.depth += 1;
        ensure!(self.depth <= MAX_PARSE_DEPTH, "nesting deeper than {MAX_PARSE_DEPTH}");
        self.i += 1; // '['
        let mut xs = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(xs));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.depth += 1;
        ensure!(self.depth <= MAX_PARSE_DEPTH, "nesting deeper than {MAX_PARSE_DEPTH}");
        self.i += 1; // '{'
        let mut pairs = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.ws();
            ensure!(self.b.get(self.i) == Some(&b'"'), "expected an object key at byte {}", self.i);
            let k = self.string()?;
            self.ws();
            ensure!(self.b.get(self.i) == Some(&b':'), "expected ':' at byte {}", self.i);
            self.i += 1;
            pairs.push((k, self.value()?));
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut o = Json::obj();
        o.set("name", "scircuit".into());
        o.set("nnz", 958_936usize.into());
        o.set("ratios", Json::Arr(vec![Json::Num(0.6441), Json::Num(0.7514)]));
        assert_eq!(
            o.render(),
            r#"{"name":"scircuit","nnz":958936,"ratios":[0.6441,0.7514]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn set_overwrites() {
        let mut o = Json::obj();
        o.set("k", 1i64.into());
        o.set("k", 2i64.into());
        assert_eq!(o.render(), r#"{"k":2}"#);
    }

    #[test]
    fn parse_roundtrips_render() {
        let mut o = Json::obj();
        o.set("name", "p2p-Gnutella04".into());
        o.set("nnz", 39_994usize.into());
        o.set("rate", 0.75f64.into());
        o.set("ok", true.into());
        o.set("none", Json::Null);
        o.set("xs", Json::Arr(vec![Json::Int(1), Json::Num(2.5), Json::Str("a\"b\n".into())]));
        let parsed = Json::parse(&o.render()).expect("own output must parse");
        assert_eq!(parsed, o);
        // And the pretty form parses to the same tree.
        assert_eq!(Json::parse(&o.render_pretty()).unwrap(), o);
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("42").unwrap(), Json::Int(42));
        assert_eq!(Json::parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(Json::parse("2.5").unwrap(), Json::Num(2.5));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("-1.25E-2").unwrap(), Json::Num(-0.0125));
        // u64-sized handles overflow i64 and widen to f64 rather than erroring.
        assert!(matches!(Json::parse("18446744073709551615").unwrap(), Json::Num(_)));
        assert!(Json::parse("-").is_err());
        assert!(Json::parse("1.2.3").is_err());
    }

    #[test]
    fn parse_escapes_and_unicode() {
        assert_eq!(Json::parse(r#""a\"b\\c\nd""#).unwrap(), Json::Str("a\"b\\c\nd".into()));
        // BMP escape, and a surrogate pair → one astral scalar.
        assert_eq!(Json::parse(r#""\u0041""#).unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse(r#""\ud83d\ude00""#).unwrap(), Json::Str("😀".into()));
        // Raw multi-byte UTF-8 passes through (2- and 4-byte scalars).
        assert_eq!(Json::parse("\"héllo 😀\"").unwrap(), Json::Str("héllo 😀".into()));
        assert!(Json::parse(r#""\ud83d""#).is_err(), "unpaired high surrogate");
        assert!(Json::parse(r#""\ude00""#).is_err(), "unpaired low surrogate");
        assert!(Json::parse(r#""\x""#).is_err(), "unknown escape");
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        for bad in ["", "{", "[1,", r#"{"a":}"#, r#"{"a":1"#, "tru", "nul", "[1] x", "\"unterminated", "{1:2}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
        }
        // Depth cap: 100 nested arrays overflow the limit cleanly.
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
        // ...but reasonable nesting is fine.
        let ok = "[".repeat(20) + &"]".repeat(20);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"s":"x","i":3,"f":2.5,"b":false,"a":[1,2],"n":null}"#).unwrap();
        assert_eq!(v.get("s").and_then(|j| j.as_str()), Some("x"));
        assert_eq!(v.get("i").and_then(|j| j.as_i64()), Some(3));
        assert_eq!(v.get("i").and_then(|j| j.as_u64()), Some(3));
        assert_eq!(v.get("i").and_then(|j| j.as_f64()), Some(3.0));
        assert_eq!(v.get("f").and_then(|j| j.as_f64()), Some(2.5));
        assert_eq!(v.get("f").and_then(|j| j.as_i64()), None, "2.5 is not an integer");
        assert_eq!(v.get("b").and_then(|j| j.as_bool()), Some(false));
        assert_eq!(v.get("a").and_then(|j| j.as_arr()).map(|a| a.len()), Some(2));
        assert!(v.get("n").is_some_and(|j| j.is_null()));
        assert!(v.get("missing").is_none());
        assert_eq!(Json::Int(-1).as_u64(), None);
    }
}
