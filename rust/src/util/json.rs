//! Tiny JSON emitter (offline build has no `serde_json`).
//!
//! Only what the metrics registry and the repro harness need: objects,
//! arrays, numbers, strings, with correct escaping. Writing only — we
//! never parse JSON.

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a key on an object (panics on non-objects —
    /// programmer error).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(&mut self, val: Json) -> &mut Self {
        match self {
            Json::Arr(xs) => xs.push(val),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn render_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    x.write(out, indent + 1, pretty);
                }
                if pretty && !xs.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !pairs.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut o = Json::obj();
        o.set("name", "scircuit".into());
        o.set("nnz", 958_936usize.into());
        o.set("ratios", Json::Arr(vec![Json::Num(0.6441), Json::Num(0.7514)]));
        assert_eq!(
            o.render(),
            r#"{"name":"scircuit","nnz":958936,"ratios":[0.6441,0.7514]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn set_overwrites() {
        let mut o = Json::obj();
        o.set("k", 1i64.into());
        o.set("k", 2i64.into());
        assert_eq!(o.render(), r#"{"k":2}"#);
    }
}
