//! Minimal property-testing support (offline build has no `proptest`).
//!
//! `check(cases, f)` runs `f` against `cases` independently seeded
//! generator states; on failure it retries with smaller size parameters
//! (a crude shrink) and reports the failing seed so the case is
//! reproducible with `QC_SEED=<seed>`.

use super::rng::Pcg32;

/// Configuration threaded into each property case.
pub struct Gen {
    pub rng: Pcg32,
    /// Size hint: generators should scale structure size with this.
    pub size: usize,
}

impl Gen {
    /// Random dimension in `[1, size]`.
    pub fn dim(&mut self) -> usize {
        1 + self.rng.below_usize(self.size)
    }
}

/// Run `prop` for `cases` randomized cases. The property panics (via
/// `assert!`) on violation. A failing seed is re-run at smaller sizes to
/// find a smaller counterexample before the final panic.
pub fn check<F>(cases: usize, base_seed: u64, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    // Env override to replay one exact case.
    if let Ok(s) = std::env::var("QC_SEED") {
        let seed: u64 = s.parse().expect("QC_SEED must be u64");
        let mut g = Gen { rng: Pcg32::seeded(seed), size: 64 };
        prop(&mut g);
        return;
    }
    for case in 0..cases {
        let seed = base_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(case as u64);
        let size = 8 + (case * 8) % 120; // ramp sizes like proptest does
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Pcg32::seeded(seed), size };
            prop(&mut g);
        });
        if let Err(err) = result {
            // Shrink: retry the same seed at smaller sizes; report smallest
            // size that still fails.
            let mut smallest = size;
            for s in (1..size).rev() {
                let r = std::panic::catch_unwind(|| {
                    let mut g = Gen { rng: Pcg32::seeded(seed), size: s };
                    prop(&mut g);
                });
                if r.is_err() {
                    smallest = s;
                } else {
                    break;
                }
            }
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed (case {case}, seed {seed}, size {size}, min failing size {smallest}).\n\
                 Replay with QC_SEED={seed}.\nOriginal failure: {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(16, 1, |g| {
            let n = g.dim();
            assert!(n >= 1);
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        // Silence the expected panic's backtrace noise.
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = std::panic::catch_unwind(|| {
            check(8, 2, |g| {
                let n = g.dim();
                assert!(n < 3, "dim too big: {n}");
            });
        });
        std::panic::set_hook(prev);
        if let Err(e) = r {
            std::panic::resume_unwind(e);
        }
    }
}
