//! Cross-cutting utilities: deterministic RNG, std-only data parallelism,
//! JSON emission, little-endian binary serialization, error handling,
//! micro-bench harness, and property-testing support.
//!
//! These exist in-tree because the build environment is offline: the
//! crate is std-only (no rayon/serde/criterion/anyhow — see Cargo.toml),
//! and the PJRT runtime's `xla` dependency is gated behind the `pjrt`
//! feature.

pub mod bench;
pub mod error;
pub mod json;
pub mod parallel;
pub mod qc;
pub mod rng;
pub mod serial;

pub use parallel::{num_threads, par_chunks, par_dynamic, par_map};
pub use rng::Pcg32;
