//! Cross-cutting utilities: deterministic RNG, std-only data parallelism,
//! JSON emission, micro-bench harness, and property-testing support.
//!
//! These exist in-tree because the build environment is offline and only
//! the `xla` crate closure is vendored (see Cargo.toml).

pub mod bench;
pub mod json;
pub mod parallel;
pub mod qc;
pub mod rng;

pub use parallel::{num_threads, par_chunks, par_dynamic, par_map};
pub use rng::Pcg32;
