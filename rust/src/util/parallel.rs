//! Minimal data-parallel helpers on `std::thread::scope`.
//!
//! The offline build has no `rayon`; the hot functional paths (hash
//! SpGEMM, generators, GNN aggregation) use these chunked scoped-thread
//! helpers instead. Work is split into contiguous index chunks, one per
//! worker, which matches the row-partitioned structure of every parallel
//! loop in this crate.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `SPGEMM_AIA_THREADS` env override,
/// otherwise available parallelism, clamped to [1, 64].
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("SPGEMM_AIA_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(1, 64)
}

/// Run `f(start, end)` over disjoint contiguous chunks of `[0, n)` in
/// parallel. `f` must be `Sync` (it is shared by reference across workers).
pub fn par_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n < 1024 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let f = &f;
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                continue;
            }
            s.spawn(move || f(start, end));
        }
    });
}

/// Parallel map over `[0, n)` producing a `Vec<T>`; each worker fills a
/// disjoint slice. Order is preserved.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        let out_ref = &out_ptr;
        par_chunks(n, move |start, end| {
            let p = *out_ref; // copy the Send wrapper out of the shared ref
            for i in start..end {
                // SAFETY: chunks are disjoint, so each index is written by
                // exactly one worker, and `out` outlives the scope.
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Work-stealing-ish dynamic scheduling for irregular per-item cost:
/// workers grab batches of `batch` indices from a shared atomic counter.
pub fn par_dynamic<F>(n: usize, batch: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n < 256 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            let f = &f;
            let next = &next;
            s.spawn(move || loop {
                let start = next.fetch_add(batch, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + batch).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Like [`par_dynamic`], but each worker owns a state value created by
/// `init` — used for reusable scratch (e.g. growable hash tables) that
/// would otherwise be reallocated per item.
pub fn par_dynamic_with<S, I, F>(n: usize, batch: usize, init: I, f: F)
where
    S: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n < 64 {
        let mut s = init();
        for i in 0..n {
            f(&mut s, i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let f = &f;
            let init = &init;
            let next = &next;
            scope.spawn(move || {
                let mut state = init();
                loop {
                    let start = next.fetch_add(batch, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + batch).min(n);
                    for i in start..end {
                        f(&mut state, i);
                    }
                }
            });
        }
    });
}

/// `*mut T` wrapper that is `Send`+`Copy` so workers can write disjoint
/// regions of one buffer.
struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_chunks_covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_chunks(n, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let v = par_map(5000, |i| i * 3);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i * 3));
    }

    #[test]
    fn par_dynamic_covers_all() {
        let n = 5000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_dynamic(n, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn small_n_runs_inline() {
        // Exercise the sequential fallback path.
        let v = par_map(10, |i| i + 1);
        assert_eq!(v, (1..=10).collect::<Vec<_>>());
    }
}
