//! Std-only error handling (the offline build has no `anyhow`): a
//! string-backed error with context chaining, the familiar `anyhow!` /
//! `bail!` / `ensure!` macros, and a `Context` extension trait for
//! `Result` and `Option`.
//!
//! The API is the subset of anyhow this crate actually uses, so callers
//! read identically to the anyhow idiom:
//!
//! ```
//! use spgemm_aia::util::error::{bail, ensure, Context, Result};
//!
//! fn parse(s: &str) -> Result<usize> {
//!     ensure!(!s.is_empty(), "empty input");
//!     let n: usize = s.trim().parse()?;
//!     if n == 0 {
//!         bail!("zero is not a valid size");
//!     }
//!     Some(n).context("unreachable")
//! }
//! assert!(parse("12").is_ok());
//! assert!(parse("").is_err());
//! ```

use std::fmt;

/// A lightweight dynamic error: one message, with outer context segments
/// prepended `"context: cause"` the way anyhow's alternate formatting
/// (`{:#}`) renders a chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context segment (outermost first, anyhow-style).
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.msg = format!("{c}: {}", self.msg);
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Any std error converts via `?`. `Error` itself deliberately does NOT
// implement `std::error::Error`, which keeps this blanket impl coherent
// with core's reflexive `From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result alias (defaults to [`Error`], second parameter kept
/// so `Result<T, ConcreteError>` call sites still read naturally).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Like [`Context::context`] but lazily built.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// [`bail!`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            // Not routed through format!: the stringified condition may
            // contain `{`/`}` (closures, struct patterns).
            return Err($crate::util::error::Error::msg(concat!("condition failed: `", stringify!($cond), "`")).into());
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

// Make the macros importable alongside the types:
// `use crate::util::error::{anyhow, bail, ensure};`
pub use crate::{anyhow, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn failing_io() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = failing_io().unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.starts_with("reading config: "), "{msg}");
    }

    #[test]
    fn macros_build_messages() {
        let name = "x";
        let e = anyhow!("unknown dataset {name}");
        assert_eq!(e.to_string(), "unknown dataset x");
        let e2 = anyhow!("{} + {}", 1, 2);
        assert_eq!(e2.to_string(), "1 + 2");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Ok(n)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(5).unwrap_err().to_string().contains("right out"));
    }

    #[test]
    fn bare_ensure_reports_condition_text() {
        fn f(s: &str) -> Result<()> {
            // Braces in the stringified condition must not be treated as
            // format placeholders.
            ensure!(!s.contains('{'));
            Ok(())
        }
        assert!(f("plain").is_ok());
        let msg = f("has{brace").unwrap_err().to_string();
        assert!(msg.contains("condition failed"), "{msg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn parse_errors_convert() {
        fn p(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert!(p("abc").is_err());
        assert_eq!(p("7").unwrap(), 7);
    }
}
