//! In-tree micro-benchmark harness (offline build has no `criterion`).
//!
//! `cargo bench` targets are `harness = false` binaries that call into
//! this module. It follows criterion's basic discipline — warmup,
//! fixed-duration sampling, mean/stddev/median over per-iteration times —
//! and prints one line per benchmark plus a machine-readable
//! `BENCH_<name>.json` dump (results + free-form meta such as the
//! engine's per-phase times) under `target/bench-results/` (override
//! with `BENCH_OUT_DIR`). CI archives these files as the perf
//! trajectory of the repo.

use crate::util::json::Json;
use std::hint::black_box;
use std::time::{Duration, Instant};

/// Re-export so bench binaries can write `bench::bb(...)`.
pub fn bb<T>(x: T) -> T {
    black_box(x)
}

/// One benchmark's collected statistics (all times in seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub mean: f64,
    pub stddev: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub iters: u64,
}

impl Stats {
    pub fn throughput_line(&self, items: f64, unit: &str) -> String {
        format!(
            "{:<44} {:>12} mean {:>10}/iter  ({:.2} {}/s)",
            self.name,
            fmt_time(self.mean),
            fmt_time(self.median),
            items / self.mean,
            unit
        )
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Benchmark runner with a fixed measurement budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_iters: u64,
    results: Vec<Stats>,
    group: String,
    /// Free-form side data emitted with the results (e.g. per-phase
    /// engine times, speedup ratios).
    meta: Json,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_iters: 10_000,
            results: Vec::new(),
            group: String::new(),
            meta: Json::obj(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Quick mode for CI / smoke runs.
        if std::env::var("BENCH_QUICK").is_ok() {
            Bencher {
                warmup: Duration::from_millis(50),
                measure: Duration::from_millis(300),
                max_iters: 200,
                ..Default::default()
            }
        } else {
            Default::default()
        }
    }

    pub fn group(&mut self, name: &str) {
        self.group = name.to_string();
        println!("\n== {name} ==");
    }

    /// Run `f` repeatedly, timing each call.
    pub fn bench<F, R>(&mut self, name: &str, mut f: F) -> Stats
    where
        F: FnMut() -> R,
    {
        let full = if self.group.is_empty() {
            name.to_string()
        } else {
            format!("{}/{}", self.group, name)
        };
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup && warm_iters < self.max_iters {
            black_box(f());
            warm_iters += 1;
        }
        // Measure.
        let mut times: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && (times.len() as u64) < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        if times.is_empty() {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let stats = summarize(&full, &times);
        println!(
            "{:<44} mean {:>10}  median {:>10}  ±{:>9}  ({} iters)",
            stats.name,
            fmt_time(stats.mean),
            fmt_time(stats.median),
            fmt_time(stats.stddev),
            stats.iters
        );
        self.results.push(stats.clone());
        stats
    }

    /// Attach a meta entry emitted alongside the results in
    /// [`Bencher::finish`] (e.g. `phases/<dataset>` → [`crate::sim::probe::PhaseTimes`] JSON).
    pub fn meta(&mut self, key: &str, v: Json) {
        self.meta.set(key, v);
    }

    /// Write results to `<BENCH_OUT_DIR|target/bench-results>/BENCH_<name>.json`.
    pub fn finish(&self, name: &str) {
        let mut arr = Json::Arr(vec![]);
        for s in &self.results {
            let mut o = Json::obj();
            o.set("name", s.name.as_str().into());
            o.set("mean_s", s.mean.into());
            o.set("median_s", s.median.into());
            o.set("stddev_s", s.stddev.into());
            o.set("min_s", s.min.into());
            o.set("max_s", s.max.into());
            o.set("iters", (s.iters as i64).into());
            arr.push(o);
        }
        let mut top = Json::obj();
        top.set("schema", "spgemm-aia-bench-v1".into());
        top.set("bench", name.into());
        top.set("quick", std::env::var("BENCH_QUICK").is_ok().into());
        top.set("results", arr);
        top.set("meta", self.meta.clone());
        let dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| "target/bench-results".to_string());
        let dir = std::path::Path::new(&dir);
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("BENCH_{name}.json"));
        if std::fs::write(&path, top.render_pretty()).is_ok() {
            println!("\nwrote {}", path.display());
        }
    }
}

fn summarize(name: &str, times: &[f64]) -> Stats {
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
    let mut sorted = times.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    Stats {
        name: name.to_string(),
        mean,
        stddev: var.sqrt(),
        median: sorted[sorted.len() / 2],
        min: sorted[0],
        max: *sorted.last().unwrap(),
        iters: times.len() as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_basic() {
        let s = summarize("t", &[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.iters, 3);
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(2.5e-3), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 µs");
        assert_eq!(fmt_time(2.5e-9), "2.5 ns");
    }

    // One test for everything env-var dependent (BENCH_QUICK /
    // BENCH_OUT_DIR), run sequentially and cleaned up at the end, so
    // parallel lib tests never race a set_var against an env read.
    #[test]
    fn bench_records_and_finish_writes_json_with_meta() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bencher::new();
        b.measure = Duration::from_millis(20);
        b.warmup = Duration::from_millis(5);
        let s = b.bench("noop", || 1 + 1);
        assert!(s.iters >= 1);
        assert_eq!(b.results.len(), 1);

        let dir = std::env::temp_dir().join("spgemm_aia_bench_out");
        let _ = std::fs::remove_dir_all(&dir);
        std::env::set_var("BENCH_OUT_DIR", &dir);
        let mut phases = Json::obj();
        phases.set("symbolic_s", 0.25.into());
        b.meta("phases/noop", phases);
        b.finish("unittest");
        std::env::remove_var("BENCH_OUT_DIR");
        std::env::remove_var("BENCH_QUICK");
        let text = std::fs::read_to_string(dir.join("BENCH_unittest.json")).expect("bench json written");
        assert!(text.contains("\"schema\""), "{text}");
        assert!(text.contains("\"results\""), "{text}");
        assert!(text.contains("symbolic_s"), "{text}");
    }
}
