//! Deterministic pseudo-random number generation.
//!
//! The build environment is offline (no `rand` crate), so we implement a
//! small, well-tested PCG-XSH-RR 64/32 generator plus the SplitMix64
//! seeder. Every stochastic component in the repo (matrix generators,
//! dataset features, property tests) threads one of these through
//! explicitly — there is no global RNG, so every experiment is exactly
//! reproducible from its seed.

/// SplitMix64: used to expand a single `u64` seed into PCG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 (O'Neill 2014). 64-bit state, 32-bit output,
/// period 2^64 per stream.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Seed a generator; `stream` selects one of 2^63 independent streams.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let inc = (stream << 1) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = init_state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below_usize(hi - lo)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from a power-law (Zipf-ish) over `[0, n)` with exponent `alpha`
    /// via inverse-transform on the continuous Pareto, clamped.
    pub fn powerlaw_index(&mut self, n: usize, alpha: f64) -> usize {
        let u = self.f64().max(1e-12);
        let x = u.powf(-1.0 / (alpha - 1.0)) - 1.0; // Pareto starting at 0
        let idx = x as usize;
        idx.min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "independent streams should rarely collide");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg32::seeded(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(5);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn powerlaw_is_skewed() {
        let mut rng = Pcg32::seeded(9);
        let n = 10_000;
        let lo = (0..n).filter(|_| rng.powerlaw_index(1000, 2.1) < 10).count();
        assert!(lo > n / 2, "power law should concentrate mass at small indices, got {lo}");
    }
}
