//! Tiny little-endian binary codec (offline build has no serde/bincode).
//!
//! Only what the versioned on-disk plan tier
//! ([`crate::spgemm::hash::planstore::DiskStore`]) needs: fixed-width
//! integers, `f64` bit patterns, and length-prefixed slices, written
//! into a `Vec<u8>` and read back with hard bounds checks. The write
//! side is infallible (it only grows a buffer); every read returns a
//! [`Result`] and fails cleanly on truncation — a corrupt or cut-short
//! file must degrade to a cache miss, never a panic or an over-sized
//! allocation (slice reads bound the declared length by the bytes
//! actually remaining before allocating).
//!
//! `f64` round-trips via [`f64::to_bits`]/[`f64::from_bits`], so values
//! (including the engine's threshold knob) are bit-identical after a
//! round trip. Writing only what `util/json.rs` writes for text, this
//! stays std-only by design.

use crate::util::error::{anyhow, ensure, Result};

/// FNV-1a offset basis — the seed [`fnv1a`] starts from.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a over a byte slice — the checksum the on-disk plan format
/// trails its payload with (catches bit flips that would otherwise
/// deserialize into structurally plausible garbage).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_seeded(FNV_OFFSET, bytes)
}

/// FNV-1a continuation: fold `bytes` into an existing hash state, so a
/// multi-array checksum (the serve daemon's per-result CSR checksum)
/// streams over its parts instead of concatenating them —
/// `fnv1a_seeded(fnv1a(a), b) == fnv1a(a ++ b)`.
pub fn fnv1a_seeded(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Append-only binary writer.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer::default()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// The bytes written so far (for checksumming before the trailer).
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn put_bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn put_u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    pub fn put_u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn put_u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    /// `usize` travels as `u64` (the format is 64-bit regardless of host).
    pub fn put_usize(&mut self, x: usize) {
        self.put_u64(x as u64);
    }

    pub fn put_f64(&mut self, x: f64) {
        self.put_u64(x.to_bits());
    }

    /// Length-prefixed (`u64` count) slice of bytes.
    pub fn put_u8_slice(&mut self, xs: &[u8]) {
        self.put_usize(xs.len());
        self.put_bytes(xs);
    }

    /// Length-prefixed (`u64` count) slice of `u32`s.
    pub fn put_u32_slice(&mut self, xs: &[u32]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_u32(x);
        }
    }

    /// Length-prefixed (`u64` count) slice of `u64`s.
    pub fn put_u64_slice(&mut self, xs: &[u64]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_u64(x);
        }
    }

    /// Length-prefixed (`u64` count) slice of `usize`s, as `u64`s.
    pub fn put_usize_slice(&mut self, xs: &[usize]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_u64(x as u64);
        }
    }
}

/// Bounds-checked binary reader over a borrowed byte slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    /// Take `n` raw bytes; errors (never panics) past the end.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(n <= self.remaining(), "truncated: need {n} bytes, {} left", self.remaining());
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn get_usize(&mut self) -> Result<usize> {
        let x = self.get_u64()?;
        usize::try_from(x).map_err(|_| anyhow!("value {x} exceeds the host usize"))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    /// Declared element count of a length-prefixed slice, bounded by
    /// what could actually fit in the remaining bytes — a corrupt
    /// length must fail here, not in an over-sized allocation.
    fn get_len(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.get_usize()?;
        ensure!(
            n.checked_mul(elem_bytes).is_some_and(|total| total <= self.remaining()),
            "truncated: {n} elements of {elem_bytes} bytes exceed the {} remaining",
            self.remaining()
        );
        Ok(n)
    }

    pub fn get_u8_vec(&mut self) -> Result<Vec<u8>> {
        let n = self.get_len(1)?;
        Ok(self.take(n)?.to_vec())
    }

    pub fn get_u32_vec(&mut self) -> Result<Vec<u32>> {
        let n = self.get_len(4)?;
        (0..n).map(|_| self.get_u32()).collect()
    }

    pub fn get_u64_vec(&mut self) -> Result<Vec<u64>> {
        let n = self.get_len(8)?;
        (0..n).map(|_| self.get_u64()).collect()
    }

    pub fn get_usize_vec(&mut self) -> Result<Vec<usize>> {
        let n = self.get_len(8)?;
        (0..n).map(|_| self.get_usize()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_primitives() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 3);
        w.put_usize(42);
        w.put_f64(-0.1);
        w.put_u8_slice(&[1, 2, 3]);
        w.put_u32_slice(&[10, 20]);
        w.put_u64_slice(&[5]);
        w.put_usize_slice(&[0, 9, 18]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.get_usize().unwrap(), 42);
        // f64 must round-trip bit-identically.
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.1f64).to_bits());
        assert_eq!(r.get_u8_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.get_u32_vec().unwrap(), vec![10, 20]);
        assert_eq!(r.get_u64_vec().unwrap(), vec![5]);
        assert_eq!(r.get_usize_vec().unwrap(), vec![0, 9, 18]);
        assert!(r.is_done());
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = Writer::new();
        w.put_u64_slice(&[1, 2, 3]);
        let bytes = w.into_bytes();
        // Cut at every possible length: reads must error, never panic.
        for cut in 0..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            let _ = r.get_u64_vec(); // ok or error — both acceptable at partial cuts
        }
        let mut r = Reader::new(&bytes[..bytes.len() - 1]);
        assert!(r.get_u64_vec().is_err(), "one missing byte must fail the slice read");
    }

    #[test]
    fn corrupt_length_fails_before_allocating() {
        let mut w = Writer::new();
        w.put_u64(u64::MAX); // absurd element count
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(r.get_u32_vec().is_err());
        let mut r = Reader::new(&bytes);
        assert!(r.get_u64_vec().is_err());
    }

    #[test]
    fn fnv_is_stable_and_sensitive() {
        let h = fnv1a(b"spgemm");
        assert_eq!(h, fnv1a(b"spgemm"), "checksum must be deterministic");
        assert_ne!(h, fnv1a(b"spgemM"));
        assert_ne!(fnv1a(&[]), 0);
    }
}
