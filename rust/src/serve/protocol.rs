//! Newline-delimited JSON line protocol — a thin shell over
//! [`ServeHandle`].
//!
//! One request object per line in, one response object per line out:
//!
//! ```text
//! > {"op":"register","matrix":{"rows":2,"cols":2,"rpt":[0,1,2],"col":[0,1],"val":[1.0,1.0]}}
//! < {"ok":true,"handle":0,"rows":2,"cols":2,"nnz":2,"structure_hash":"9c30d5bc8f1b8655"}
//! > {"op":"register","dataset":"scircuit","seed":7}
//! < {"ok":true,"handle":1,...}
//! > {"op":"multiply","a":0,"b":0}
//! < {"ok":true,"nnz":2,"checksum":"…","plan":"fresh","plan_s":…,"fill_s":…,"symbolic_s":…}
//! > {"op":"multiply","a":0,"b":0,"values":true}
//! < {"ok":true,...,"plan":"mem","symbolic_s":0.0,"rpt":[…],"col":[…],"val":[…]}
//! > {"op":"multiply","a":0,"b":0,"planner":"estimated"}
//! < {"ok":true,...,"plan":"estimated",...}   (cold one-shot: speculative plan, never stored)
//! > {"op":"multiply","a":0,"b":0,"mask":0}
//! < {"ok":true,...}   (C = M ⊙ (A·B); mask = a registered handle's structure)
//! > {"op":"stats"}            < {"ok":true,"stats":{…}}
//! > {"op":"release","handle":0}  < {"ok":true,"released":0}
//! > {"op":"ping"}             < {"ok":true,"pong":true}
//! > {"op":"shutdown"}         < {"ok":true,"stopping":true}   (daemon drains and exits)
//! ```
//!
//! Failures are `{"ok":false,"error":"<code>","message":"…"}` with the
//! stable codes of [`ServeError::code`] (plus `bad_request` for parse
//! failures); a `busy` response additionally carries `queue_depth` /
//! `queue_capacity` so clients can back off informedly. Checksums and
//! structure hashes travel as 16-digit hex strings (JSON integers are
//! `i64` on the wire; `u64` values must not go through them).
//!
//! [`handle_line`] is the whole dispatcher — the socket session
//! ([`super::session`]) only frames lines and moves bytes, so
//! in-process tests of `handle_line` cover the daemon's full request
//! path short of I/O.

use super::{MultiplyOutcome, ServeError, ServeHandle};
use crate::sparse::Csr;
use crate::spgemm::hash::PlannerPolicy;
use crate::util::error::{anyhow, bail, Result};
use crate::util::json::Json;

/// One parsed client request.
#[derive(Debug)]
pub enum Request {
    /// Upload an operand (inline CSR or a named generated dataset).
    Register { matrix: Csr },
    /// Multiply two registered operands; `values` asks for the full
    /// result arrays instead of just `nnz` + checksum; `planner`
    /// overrides the daemon's default policy for this request
    /// (`"exact"` / `"estimated"` / `"auto"`); `mask` names a third
    /// registered handle whose *structure* masks the output
    /// (`C = M ⊙ (A·B)` — `"mask"` equal to `a` is the triangle-
    /// counting idiom).
    Multiply { a: u64, b: u64, values: bool, planner: Option<PlannerPolicy>, mask: Option<u64> },
    Release { handle: u64 },
    Stats,
    Ping,
    Shutdown,
}

/// `u64` as the protocol ships it: 16 hex digits.
pub fn hex64(x: u64) -> String {
    format!("{x:016x}")
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let doc = Json::parse(line)?;
    let op = doc.get("op").and_then(Json::as_str).ok_or_else(|| anyhow!("missing string field 'op'"))?;
    match op {
        "register" => parse_register(&doc),
        "multiply" => Ok(Request::Multiply {
            a: field_u64(&doc, "a")?,
            b: field_u64(&doc, "b")?,
            values: doc.get("values").and_then(Json::as_bool).unwrap_or(false),
            planner: parse_planner(&doc)?,
            mask: match doc.get("mask") {
                None => None,
                Some(v) => Some(
                    v.as_u64().ok_or_else(|| anyhow!("field 'mask' must be a matrix handle (integer)"))?,
                ),
            },
        }),
        "release" => Ok(Request::Release { handle: field_u64(&doc, "handle")? }),
        "stats" => Ok(Request::Stats),
        "ping" => Ok(Request::Ping),
        "shutdown" => Ok(Request::Shutdown),
        other => bail!("unknown op {other:?}"),
    }
}

/// Optional per-request planner override; an unknown value is a
/// `bad_request`, never a silent fallback to the daemon default.
fn parse_planner(doc: &Json) -> Result<Option<PlannerPolicy>> {
    match doc.get("planner") {
        None => Ok(None),
        Some(v) => {
            let s = v.as_str().ok_or_else(|| anyhow!("field 'planner' must be a string"))?;
            PlannerPolicy::parse(s)
                .map(Some)
                .ok_or_else(|| anyhow!("unknown planner {s:?} (expected exact, estimated, or auto)"))
        }
    }
}

fn field_u64(doc: &Json, key: &str) -> Result<u64> {
    doc.get(key)
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("missing or non-integer field {key:?}"))
}

fn usize_array(obj: &Json, key: &str) -> Result<Vec<usize>> {
    obj.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("matrix.{key} must be an array"))?
        .iter()
        .map(|v| {
            v.as_u64()
                .and_then(|x| usize::try_from(x).ok())
                .ok_or_else(|| anyhow!("matrix.{key} entries must be non-negative integers"))
        })
        .collect()
}

fn parse_register(doc: &Json) -> Result<Request> {
    if let Some(name) = doc.get("dataset").and_then(Json::as_str) {
        let seed = doc.get("seed").and_then(Json::as_u64).unwrap_or(crate::repro::SEED);
        if let Some(ds) = crate::gen::table2_by_name(name) {
            return Ok(Request::Register { matrix: (ds.gen)(seed) });
        }
        if let Some(ds) = crate::gen::table3_by_name(name) {
            return Ok(Request::Register { matrix: (ds.gen)(seed) });
        }
        bail!("unknown dataset {name:?} (see `spgemm-aia info`)");
    }
    let m = doc.get("matrix").ok_or_else(|| anyhow!("register needs 'dataset' or 'matrix'"))?;
    let rows = field_u64(m, "rows")? as usize;
    let cols = field_u64(m, "cols")? as usize;
    let rpt = usize_array(m, "rpt")?;
    let col: Vec<u32> = usize_array(m, "col")?
        .into_iter()
        .map(|c| u32::try_from(c).map_err(|_| anyhow!("matrix.col entry exceeds u32")))
        .collect::<Result<_>>()?;
    let val: Vec<f64> = m
        .get("val")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("matrix.val must be an array"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow!("matrix.val entries must be numbers")))
        .collect::<Result<_>>()?;
    // Validating constructor: socket input never reaches the unchecked
    // kernels without a full structural check.
    let matrix = Csr::new(rows, cols, rpt, col, val)?;
    Ok(Request::Register { matrix })
}

fn ok_response() -> Json {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o
}

/// `{"ok":false,...}` with a stable code.
pub fn error_response(code: &str, message: &str) -> Json {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(false));
    o.set("error", Json::Str(code.into()));
    o.set("message", Json::Str(message.into()));
    o
}

fn serve_error_response(e: &ServeError) -> Json {
    let mut o = error_response(e.code(), &e.to_string());
    if let ServeError::Busy { depth, capacity } = e {
        o.set("queue_depth", (*depth as i64).into());
        o.set("queue_capacity", (*capacity as i64).into());
    }
    o
}

fn multiply_response(out: &MultiplyOutcome, values: bool) -> Json {
    let mut o = ok_response();
    o.set("nnz", (out.nnz as i64).into());
    o.set("checksum", Json::Str(hex64(out.checksum)));
    o.set("plan", Json::Str(out.source.label().into()));
    o.set("plan_s", out.plan_s.into());
    o.set("fill_s", out.fill_s.into());
    o.set("symbolic_s", out.symbolic_s.into());
    if values {
        o.set("rows", (out.c.n_rows as i64).into());
        o.set("cols", (out.c.n_cols as i64).into());
        o.set("rpt", Json::Arr(out.c.rpt.iter().map(|&r| (r as i64).into()).collect()));
        o.set("col", Json::Arr(out.c.col.iter().map(|&c| (c as i64).into()).collect()));
        // f64 values render with round-trip precision (the emitter uses
        // shortest-exact formatting), so "values":true is lossless.
        o.set("val", Json::Arr(out.c.val.iter().map(|&v| v.into()).collect()));
    }
    o
}

/// Process one request line against a handle. Returns the response
/// line (no trailing newline) and whether the daemon should stop
/// (`shutdown` op).
pub fn handle_line(h: &ServeHandle, client: u64, line: &str) -> (String, bool) {
    let request = match parse_request(line) {
        Ok(r) => r,
        Err(e) => return (error_response("bad_request", &format!("{e:#}")).render(), false),
    };
    let response = match request {
        Request::Ping => {
            let mut o = ok_response();
            o.set("pong", Json::Bool(true));
            o
        }
        Request::Register { matrix } => {
            // Response fields come off the matrix before it moves into
            // the registry; the hash memo moves with it.
            let (rows, cols, nnz) = (matrix.n_rows, matrix.n_cols, matrix.nnz());
            let hash = matrix.structure_hash();
            match h.register(matrix) {
                Ok(handle) => {
                    let mut o = ok_response();
                    o.set("handle", (handle.raw() as i64).into());
                    o.set("rows", (rows as i64).into());
                    o.set("cols", (cols as i64).into());
                    o.set("nnz", (nnz as i64).into());
                    o.set("structure_hash", Json::Str(hex64(hash)));
                    o
                }
                Err(e) => serve_error_response(&e),
            }
        }
        Request::Multiply { a, b, values, planner, mask } => {
            match h.multiply_by_handle_masked_policy(client, a, b, mask, planner) {
                Ok(out) => multiply_response(&out, values),
                Err(e) => serve_error_response(&e),
            }
        }
        Request::Release { handle } => match h.release(handle) {
            Ok(()) => {
                let mut o = ok_response();
                o.set("released", (handle as i64).into());
                o
            }
            Err(e) => serve_error_response(&e),
        },
        Request::Stats => {
            let mut o = ok_response();
            o.set("stats", h.stats_json());
            o
        }
        Request::Shutdown => {
            let mut o = ok_response();
            o.set("stopping", Json::Bool(true));
            return (o.render(), true);
        }
    };
    (response.render(), false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{Server, ServeConfig};
    use crate::spgemm::hash::TieredStore;

    fn mem_server() -> Server {
        Server::start_with_store(
            &ServeConfig { queue_capacity: 8, n_streams: 2, ..ServeConfig::default() },
            TieredStore::mem_only(),
        )
    }

    /// A small but non-trivial CSR as its inline-register JSON line.
    fn inline_register_line() -> String {
        // 4x4: row 0 -> {0,2}, row 1 -> {1}, row 2 -> {0,3}, row 3 -> {}
        r#"{"op":"register","matrix":{"rows":4,"cols":4,"rpt":[0,2,3,5,5],"col":[0,2,1,0,3],"val":[1.0,2.0,3.0,4.5,-1.25]}}"#
            .to_string()
    }

    #[test]
    fn parse_request_covers_every_op() {
        assert!(matches!(parse_request(r#"{"op":"ping"}"#).unwrap(), Request::Ping));
        assert!(matches!(parse_request(r#"{"op":"stats"}"#).unwrap(), Request::Stats));
        assert!(matches!(parse_request(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown));
        assert!(matches!(
            parse_request(r#"{"op":"release","handle":7}"#).unwrap(),
            Request::Release { handle: 7 }
        ));
        match parse_request(r#"{"op":"multiply","a":1,"b":2,"values":true}"#).unwrap() {
            Request::Multiply { a: 1, b: 2, values: true, planner: None, mask: None } => {}
            other => panic!("bad multiply parse: {other:?}"),
        }
        match parse_request(r#"{"op":"multiply","a":1,"b":2,"planner":"estimated"}"#).unwrap() {
            Request::Multiply { planner: Some(PlannerPolicy::Estimated), values: false, .. } => {}
            other => panic!("bad planner parse: {other:?}"),
        }
        match parse_request(r#"{"op":"multiply","a":1,"b":2,"mask":3}"#).unwrap() {
            Request::Multiply { a: 1, b: 2, mask: Some(3), .. } => {}
            other => panic!("bad mask parse: {other:?}"),
        }
        match parse_request(&inline_register_line()).unwrap() {
            Request::Register { matrix } => {
                assert_eq!((matrix.n_rows, matrix.nnz()), (4, 5));
            }
            other => panic!("bad register parse: {other:?}"),
        }
    }

    #[test]
    fn parse_request_rejects_malformed_input() {
        for bad in [
            "not json at all",
            r#"{"no_op":1}"#,
            r#"{"op":"frobnicate"}"#,
            r#"{"op":"multiply","a":1}"#,
            r#"{"op":"multiply","a":"x","b":2}"#,
            r#"{"op":"multiply","a":1,"b":2,"planner":"frobnicate"}"#,
            r#"{"op":"multiply","a":1,"b":2,"planner":7}"#,
            r#"{"op":"multiply","a":1,"b":2,"mask":"x"}"#,
            r#"{"op":"release"}"#,
            r#"{"op":"register"}"#,
            r#"{"op":"register","dataset":"no-such-dataset"}"#,
            // Structurally invalid CSR: rpt[last] != nnz.
            r#"{"op":"register","matrix":{"rows":1,"cols":1,"rpt":[0,2],"col":[0],"val":[1.0]}}"#,
        ] {
            assert!(parse_request(bad).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn line_session_register_multiply_stats_release() {
        let server = mem_server();
        let h = server.handle();
        let client = h.new_client();
        let (resp, stop) = handle_line(&h, client, &inline_register_line());
        assert!(!stop);
        let reg = Json::parse(&resp).unwrap();
        assert_eq!(reg.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
        let handle = reg.get("handle").and_then(Json::as_u64).unwrap();
        assert_eq!(reg.get("nnz").and_then(Json::as_i64), Some(5));
        // First multiply: fresh plan; values requested.
        let line = format!(r#"{{"op":"multiply","a":{handle},"b":{handle},"values":true}}"#);
        let (resp1, _) = handle_line(&h, client, &line);
        let m1 = Json::parse(&resp1).unwrap();
        assert_eq!(m1.get("plan").and_then(Json::as_str), Some("fresh"), "{resp1}");
        assert!(m1.get("rpt").and_then(Json::as_arr).is_some_and(|a| a.len() == 5));
        // Second multiply: memory hit, zero symbolic, identical checksum.
        let (resp2, _) = handle_line(&h, client, &line);
        let m2 = Json::parse(&resp2).unwrap();
        assert_eq!(m2.get("plan").and_then(Json::as_str), Some("mem"), "{resp2}");
        assert_eq!(m2.get("symbolic_s").and_then(Json::as_f64), Some(0.0));
        assert_eq!(
            m1.get("checksum").and_then(Json::as_str),
            m2.get("checksum").and_then(Json::as_str),
            "hit and miss must be bit-identical"
        );
        assert_eq!(m1.get("nnz").and_then(Json::as_i64), m2.get("nnz").and_then(Json::as_i64));
        // Stats reconcile.
        let (resp, _) = handle_line(&h, client, r#"{"op":"stats"}"#);
        let stats = Json::parse(&resp).unwrap();
        let s = stats.get("stats").unwrap();
        assert_eq!(s.get("requests").and_then(Json::as_i64), Some(2), "{resp}");
        assert_eq!(s.get("plan_hits").and_then(Json::as_i64), Some(1));
        assert_eq!(s.get("plan_misses").and_then(Json::as_i64), Some(1));
        // Release, then the handle is unknown.
        let (resp, _) = handle_line(&h, client, &format!(r#"{{"op":"release","handle":{handle}}}"#));
        assert_eq!(Json::parse(&resp).unwrap().get("ok").and_then(Json::as_bool), Some(true));
        let (resp, _) = handle_line(&h, client, &line);
        let err = Json::parse(&resp).unwrap();
        assert_eq!(err.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(err.get("error").and_then(Json::as_str), Some("unknown_handle"), "{resp}");
        server.shutdown();
    }

    /// A cold one-shot multiply with `"planner":"estimated"` answers
    /// `plan:"estimated"` with the same checksum as the exact path, and
    /// the store's `stores` counter never moves for it.
    #[test]
    fn estimated_multiply_request_round_trips() {
        let server = mem_server();
        let h = server.handle();
        let client = h.new_client();
        let (resp, _) = handle_line(&h, client, &inline_register_line());
        let handle = Json::parse(&resp).unwrap().get("handle").and_then(Json::as_u64).unwrap();
        let est_line = format!(r#"{{"op":"multiply","a":{handle},"b":{handle},"planner":"estimated"}}"#);
        let (resp1, _) = handle_line(&h, client, &est_line);
        let m1 = Json::parse(&resp1).unwrap();
        assert_eq!(m1.get("plan").and_then(Json::as_str), Some("estimated"), "{resp1}");
        assert_eq!(m1.get("symbolic_s").and_then(Json::as_f64), Some(0.0));
        // The exact path agrees bit-for-bit.
        let (resp2, _) = handle_line(&h, client, &format!(r#"{{"op":"multiply","a":{handle},"b":{handle}}}"#));
        let m2 = Json::parse(&resp2).unwrap();
        assert_eq!(m2.get("plan").and_then(Json::as_str), Some("fresh"), "{resp2}");
        assert_eq!(
            m1.get("checksum").and_then(Json::as_str),
            m2.get("checksum").and_then(Json::as_str),
            "estimated and exact must be bit-identical"
        );
        // Stats: the estimated request is its own bucket.
        let (resp, _) = handle_line(&h, client, r#"{"op":"stats"}"#);
        let stats = Json::parse(&resp).unwrap();
        let s = stats.get("stats").unwrap();
        assert_eq!(s.get("plan_estimated").and_then(Json::as_i64), Some(1), "{resp}");
        assert_eq!(s.get("plan_misses").and_then(Json::as_i64), Some(1));
        server.shutdown();
    }

    #[test]
    fn bad_lines_get_bad_request_and_shutdown_stops() {
        let server = mem_server();
        let h = server.handle();
        let client = h.new_client();
        let (resp, stop) = handle_line(&h, client, "][ not json");
        assert!(!stop);
        let err = Json::parse(&resp).unwrap();
        assert_eq!(err.get("error").and_then(Json::as_str), Some("bad_request"), "{resp}");
        let (resp, stop) = handle_line(&h, client, r#"{"op":"ping"}"#);
        assert!(!stop);
        assert!(resp.contains("\"pong\":true"));
        let (resp, stop) = handle_line(&h, client, r#"{"op":"shutdown"}"#);
        assert!(stop, "shutdown must signal the session loop to stop");
        assert!(resp.contains("\"stopping\":true"));
        server.shutdown();
    }
}
