//! SpGEMM service daemon: a resident executor over one shared plan
//! store (ROADMAP "Service daemon"; DESIGN.md §2e).
//!
//! Everything before this module amortized the symbolic phase *within*
//! a process (plan slots, the batch executor's cache) or across
//! processes *via disk*. The daemon closes the remaining gap: a
//! [`Server`] owns one [`TieredStore`] and one worker thread with a
//! resident [`BatchExecutor`] built over a **clone** of that store
//! (clones share tiers and counters), so every client session pools
//! plans in memory — client 2's first multiply of a structure client 1
//! already planned is a memory hit, no disk round trip, no replan.
//!
//! Shape of the thing:
//!
//! - [`ServeHandle`] — the in-process API (clonable, thread-safe):
//!   `register`/`release` matrices through the generation-counted
//!   [`registry::MatrixRegistry`], `multiply` by handle, `stats`.
//!   The Unix-socket line protocol ([`protocol`], [`session`]) is a
//!   thin shell over this handle — every test that drives the handle
//!   drives the daemon's whole request path short of framing.
//! - [`queue::RequestQueue`] — bounded admission with explicit
//!   backpressure: a full queue returns [`ServeError::Busy`]
//!   immediately (the client retries), never unbounded growth, never a
//!   parked connection thread.
//! - One worker thread — requests execute serially on the resident
//!   executor (the engine already parallelizes *inside* a multiply;
//!   serializing products keeps plan-store accounting exact and the
//!   memory peak at one product).
//!
//! Every response carries where its plan came from
//! ([`PlanSource`]) and the symbolic seconds the call actually paid —
//! the CI smoke test asserts a repeated product reports `plan: "mem"`
//! with `symbolic_s == 0` and a bit-identical checksum.

pub mod protocol;
pub mod queue;
pub mod registry;
#[cfg(unix)]
pub mod session;

pub use registry::{HandleId, MatrixRegistry};

use crate::coordinator::batch::{BatchExecutor, PlanSource};
use crate::coordinator::metrics::Metrics;
use crate::sparse::Csr;
use crate::spgemm::hash::{Mask, PlannerPolicy, StoreStats, TieredStore};
use crate::util::json::Json;
use crate::util::serial::{fnv1a_seeded, FNV_OFFSET};
use queue::{QueueReceiver, RequestQueue, SubmitError};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

/// Daemon knobs (socket path lives with [`session::run_daemon`], not
/// here — the in-process server has no socket).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Max enqueued (accepted, unstarted) multiplies; beyond this,
    /// submissions bounce with [`ServeError::Busy`].
    pub queue_capacity: usize,
    /// Stream count of the resident executor's bin scheduler.
    pub n_streams: usize,
    /// Disk tier of the daemon's plan store; `None` = memory only.
    pub plan_cache: Option<PathBuf>,
    /// Default planner policy for multiply requests; a request may
    /// override it with an explicit `planner` field. Whatever the
    /// policy, store-backed requests stay exact — speculation only
    /// applies to fully-cold one-shot products, and speculative plans
    /// never enter the shared store.
    pub planner: PlannerPolicy,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig { queue_capacity: 64, n_streams: 4, plan_cache: None, planner: PlannerPolicy::Exact }
    }
}

/// Flag-over-env plan-cache resolution for the daemon.
///
/// `serve` builds its store from this *explicitly* instead of reading
/// the process-wide `OnceLock` default: that cell latches on first
/// read, so any executor constructed before flag parsing would have
/// pinned whatever the cell held at that moment — under a daemon,
/// silently the wrong cache directory for its whole lifetime.
/// Empty values count as unset.
pub fn resolve_plan_cache(flag: Option<&str>, env: Option<&str>) -> Option<PathBuf> {
    flag.filter(|s| !s.is_empty()).or_else(|| env.filter(|s| !s.is_empty())).map(PathBuf::from)
}

/// Content checksum of a result matrix: shape, row pointers, columns,
/// and value *bit patterns*, FNV-1a-chained in order. Two responses
/// with equal checksums (and equal nnz) are bit-identical products —
/// what the smoke test asserts across hit/miss and across processes.
pub fn csr_checksum(c: &Csr) -> u64 {
    let mut h = fnv1a_seeded(FNV_OFFSET, &(c.n_rows as u64).to_le_bytes());
    h = fnv1a_seeded(h, &(c.n_cols as u64).to_le_bytes());
    for &r in &c.rpt {
        h = fnv1a_seeded(h, &(r as u64).to_le_bytes());
    }
    for &col in &c.col {
        h = fnv1a_seeded(h, &col.to_le_bytes());
    }
    for &v in &c.val {
        h = fnv1a_seeded(h, &v.to_bits().to_le_bytes());
    }
    h
}

/// Everything a multiply request answers with.
#[derive(Clone, Debug)]
pub struct MultiplyOutcome {
    pub c: Csr,
    /// `c.nnz()`, pre-extracted for responses that drop the values.
    pub nnz: usize,
    /// [`csr_checksum`] of `c`.
    pub checksum: u64,
    /// Where the plan came from (`fresh`/`mem`/`disk`/`delta`/
    /// `estimated` — `delta` when a re-registered, mutated matrix
    /// routed through the dirty-row delta planner, `estimated` when a
    /// fully-cold one-shot request ran the speculative planner).
    pub source: PlanSource,
    /// Seconds resolving the plan (lookup + validation; plus
    /// grouping/symbolic when fresh, or the dirty-row patch when
    /// delta).
    pub plan_s: f64,
    /// Seconds in the numeric fill.
    pub fill_s: f64,
    /// Symbolic seconds this request paid — `0.0` on any plan hit;
    /// on a delta patch, the dirty rows' counting cost only.
    pub symbolic_s: f64,
}

/// Request-path failures, each with a stable wire code.
#[derive(Debug)]
pub enum ServeError {
    /// Queue at capacity — retry later (explicit backpressure).
    Busy { depth: usize, capacity: usize },
    /// Handle released, stale, or never issued.
    UnknownHandle(u64),
    /// Operand shapes don't compose.
    BadRequest(String),
    /// Daemon is draining; no new work.
    ShuttingDown,
    /// Worker thread is gone (shut down or died).
    WorkerGone,
}

impl ServeError {
    /// Stable machine-readable code — the line protocol's `error`
    /// field.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Busy { .. } => "busy",
            ServeError::UnknownHandle(_) => "unknown_handle",
            ServeError::BadRequest(_) => "bad_request",
            ServeError::ShuttingDown => "shutting_down",
            ServeError::WorkerGone => "worker_gone",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Busy { depth, capacity } => {
                write!(f, "queue full ({depth}/{capacity} pending) — retry later")
            }
            ServeError::UnknownHandle(raw) => write!(f, "unknown matrix handle {raw}"),
            ServeError::BadRequest(msg) => write!(f, "{msg}"),
            ServeError::ShuttingDown => write!(f, "daemon is shutting down"),
            ServeError::WorkerGone => write!(f, "worker thread is gone"),
        }
    }
}

/// Per-client counters (keyed by the session id
/// [`ServeHandle::new_client`] mints).
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientStats {
    pub requests: u64,
    pub hits: u64,
    pub misses: u64,
    /// Requests served by dirty-row delta patching (neither hit nor
    /// miss — `requests = hits + misses + deltas + estimated`).
    pub deltas: u64,
    /// Requests served by the speculative estimated planner (fully-cold
    /// one-shot products under an estimated policy; neither hit nor
    /// miss).
    pub estimated: u64,
}

/// Daemon-lifetime counters.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Multiplies executed (accepted *and* completed by the worker).
    pub requests: u64,
    /// Submissions bounced off the full queue.
    pub busy_rejections: u64,
    /// Requests served from the memory tier (or an in-batch share).
    pub plan_hits: u64,
    /// Requests served from the validated disk tier.
    pub disk_hits: u64,
    /// Requests that had to build a plan.
    pub plan_misses: u64,
    /// Requests served by patching the previous same-shape plan's
    /// dirty rows ([`PlanSource::Delta`]) — e.g. a client re-registered
    /// a mutated matrix. Neither a hit nor a miss in
    /// [`ServeStats::hit_rate`].
    pub plan_deltas: u64,
    /// Requests served by the speculative estimated planner
    /// ([`PlanSource::Estimated`]): fully-cold one-shot products under
    /// an estimated policy. The plan was guessed, not reused or built
    /// exactly — neither a hit nor a miss in [`ServeStats::hit_rate`],
    /// and never written to the shared store.
    pub plan_estimated: u64,
    /// Matrices registered over the daemon's lifetime.
    pub registered: u64,
    /// Handles released.
    pub released: u64,
    pub per_client: BTreeMap<u64, ClientStats>,
}

impl ServeStats {
    /// Fraction of executed multiplies that skipped the symbolic phase.
    /// Delta-patched requests re-ran it (over dirty rows only) and
    /// estimated requests never built an exact plan at all, so both are
    /// excluded from both sides of the fraction.
    pub fn hit_rate(&self) -> f64 {
        let hits = self.plan_hits + self.disk_hits;
        let total = hits + self.plan_misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

/// Jobs the worker thread consumes.
enum Job {
    Multiply {
        a: Arc<Csr>,
        b: Arc<Csr>,
        /// Output mask for `C = mask ⊙ (A·B)` — the wire's `"mask"`
        /// handle, resolved to the named matrix's structure.
        mask: Option<Mask>,
        client: u64,
        planner: PlannerPolicy,
        reply: mpsc::Sender<MultiplyOutcome>,
    },
    /// Park the worker until the guard drops (tests use this to pin
    /// the queue at a known depth and exercise backpressure
    /// deterministically).
    Quiesce { entered: mpsc::Sender<()>, release: mpsc::Receiver<()> },
    Shutdown,
}

/// Clonable, thread-safe client face of a running [`Server`] — one per
/// connection thread, or handed around freely in-process.
#[derive(Clone)]
pub struct ServeHandle {
    queue: RequestQueue<Job>,
    registry: Arc<Mutex<MatrixRegistry>>,
    stats: Arc<Mutex<ServeStats>>,
    store: TieredStore,
    planner: PlannerPolicy,
    shutting_down: Arc<AtomicBool>,
    next_client: Arc<AtomicU64>,
}

impl ServeHandle {
    /// Mint a client/session id (per-client stats key).
    pub fn new_client(&self) -> u64 {
        self.next_client.fetch_add(1, Ordering::SeqCst)
    }

    fn stats_lock(&self) -> std::sync::MutexGuard<'_, ServeStats> {
        self.stats.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn registry_lock(&self) -> std::sync::MutexGuard<'_, MatrixRegistry> {
        self.registry.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Register an operand; its structure hash is computed here, once.
    pub fn register(&self, m: Csr) -> Result<HandleId, ServeError> {
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        let h = self.registry_lock().register(Arc::new(m));
        self.stats_lock().registered += 1;
        Ok(h)
    }

    /// The matrix behind a wire handle.
    pub fn resolve(&self, raw: u64) -> Result<Arc<Csr>, ServeError> {
        self.registry_lock()
            .resolve(HandleId::from_raw(raw))
            .ok_or(ServeError::UnknownHandle(raw))
    }

    /// Release a handle (generation-bumped: it can never alias again).
    pub fn release(&self, raw: u64) -> Result<(), ServeError> {
        if !self.registry_lock().release(HandleId::from_raw(raw)) {
            return Err(ServeError::UnknownHandle(raw));
        }
        self.stats_lock().released += 1;
        Ok(())
    }

    /// Registered (live) matrices right now.
    pub fn registered_live(&self) -> usize {
        self.registry_lock().len()
    }

    /// Enqueue one multiply and wait for its result. Backpressure is
    /// explicit: a full queue fails *now* with [`ServeError::Busy`]
    /// instead of blocking the caller behind unbounded work.
    pub fn multiply(&self, client: u64, a: Arc<Csr>, b: Arc<Csr>) -> Result<MultiplyOutcome, ServeError> {
        self.multiply_policy(client, a, b, None)
    }

    /// [`ServeHandle::multiply`] with an explicit per-request planner
    /// policy; `None` runs the daemon's configured default
    /// ([`ServeConfig::planner`]). Store-backed requests resolve
    /// exactly under every policy — only a fully-cold one-shot product
    /// speculates.
    pub fn multiply_policy(
        &self,
        client: u64,
        a: Arc<Csr>,
        b: Arc<Csr>,
        policy: Option<PlannerPolicy>,
    ) -> Result<MultiplyOutcome, ServeError> {
        self.multiply_masked_policy(client, a, b, None, policy)
    }

    /// [`ServeHandle::multiply_policy`] with an optional output mask:
    /// `C = mask ⊙ (A·B)`, planned and filled by the masked kernels so
    /// rejected entries are never materialized. The mask joins the
    /// plan fingerprint, so masked plans pool in the shared store like
    /// any other. A mask whose shape is not the output shape is a
    /// [`ServeError::BadRequest`].
    pub fn multiply_masked_policy(
        &self,
        client: u64,
        a: Arc<Csr>,
        b: Arc<Csr>,
        mask: Option<Mask>,
        policy: Option<PlannerPolicy>,
    ) -> Result<MultiplyOutcome, ServeError> {
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        if a.n_cols != b.n_rows {
            return Err(ServeError::BadRequest(format!(
                "shape mismatch: a is {}x{}, b is {}x{}",
                a.n_rows, a.n_cols, b.n_rows, b.n_cols
            )));
        }
        if let Some(m) = &mask {
            if m.shape() != (a.n_rows, b.n_cols) {
                return Err(ServeError::BadRequest(format!(
                    "mask shape mismatch: mask is {}x{}, output is {}x{}",
                    m.n_rows(),
                    m.n_cols(),
                    a.n_rows,
                    b.n_cols
                )));
            }
        }
        let planner = policy.unwrap_or(self.planner);
        let (reply, result) = mpsc::channel();
        match self.queue.submit(Job::Multiply { a, b, mask, client, planner, reply }) {
            Ok(_) => {}
            Err(SubmitError::Busy(_)) => {
                self.stats_lock().busy_rejections += 1;
                return Err(ServeError::Busy { depth: self.queue.depth(), capacity: self.queue.capacity() });
            }
            Err(SubmitError::Closed(_)) => return Err(ServeError::WorkerGone),
        }
        result.recv().map_err(|_| ServeError::WorkerGone)
    }

    /// [`ServeHandle::multiply`] with both operands named by handle.
    pub fn multiply_by_handle(&self, client: u64, a_raw: u64, b_raw: u64) -> Result<MultiplyOutcome, ServeError> {
        self.multiply_by_handle_policy(client, a_raw, b_raw, None)
    }

    /// [`ServeHandle::multiply_policy`] with both operands named by
    /// handle (the line protocol's `multiply` op lands here).
    pub fn multiply_by_handle_policy(
        &self,
        client: u64,
        a_raw: u64,
        b_raw: u64,
        policy: Option<PlannerPolicy>,
    ) -> Result<MultiplyOutcome, ServeError> {
        self.multiply_by_handle_masked_policy(client, a_raw, b_raw, None, policy)
    }

    /// [`ServeHandle::multiply_masked_policy`] with everything named by
    /// handle — the wire's optional `"mask"` field lands here. The mask
    /// handle names any registered matrix; only its *structure* is
    /// used (values are ignored), so `mask == a` is the triangle-
    /// counting idiom `A ⊙ (A·A)` with zero extra uploads.
    pub fn multiply_by_handle_masked_policy(
        &self,
        client: u64,
        a_raw: u64,
        b_raw: u64,
        mask_raw: Option<u64>,
        policy: Option<PlannerPolicy>,
    ) -> Result<MultiplyOutcome, ServeError> {
        let a = self.resolve(a_raw)?;
        let b = self.resolve(b_raw)?;
        let mask = match mask_raw {
            None => None,
            Some(raw) => Some(Mask::from_structure(&self.resolve(raw)?)),
        };
        self.multiply_masked_policy(client, a, b, mask, policy)
    }

    /// Park the worker until the returned guard drops. Submitted
    /// through the queue like any job, so it runs after everything
    /// already accepted; while parked, accepted jobs pile up to
    /// capacity and further submissions bounce — the deterministic
    /// backpressure fixture.
    pub fn quiesce(&self) -> Result<QuiesceGuard, ServeError> {
        let (entered_tx, entered_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel();
        match self.queue.submit(Job::Quiesce { entered: entered_tx, release: release_rx }) {
            Ok(_) => {}
            Err(SubmitError::Busy(_)) => {
                return Err(ServeError::Busy { depth: self.queue.depth(), capacity: self.queue.capacity() })
            }
            Err(SubmitError::Closed(_)) => return Err(ServeError::WorkerGone),
        }
        entered_rx.recv().map_err(|_| ServeError::WorkerGone)?;
        Ok(QuiesceGuard { _release: release_tx })
    }

    /// Accepted-but-unstarted multiplies right now.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    pub fn queue_capacity(&self) -> usize {
        self.queue.capacity()
    }

    /// Snapshot of the daemon-lifetime counters.
    pub fn stats(&self) -> ServeStats {
        self.stats_lock().clone()
    }

    /// The shared plan store's own counters.
    pub fn store_stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// A clone of the daemon's shared plan store (clones share tiers
    /// and counters).
    pub fn store(&self) -> TieredStore {
        self.store.clone()
    }

    /// Export daemon counters under `serve.*` (and the shared store
    /// under `serve.store.*`, per-client under `serve.client.<id>.*`).
    pub fn export_metrics(&self, m: &mut Metrics) {
        let st = self.stats();
        m.gauge("serve.queue_depth", self.queue.depth() as f64);
        m.gauge("serve.queue_capacity", self.queue.capacity() as f64);
        m.inc("serve.requests", st.requests);
        m.inc("serve.busy_rejections", st.busy_rejections);
        m.inc("serve.plan_hits", st.plan_hits);
        m.inc("serve.disk_hits", st.disk_hits);
        m.inc("serve.plan_misses", st.plan_misses);
        m.inc("serve.plan_deltas", st.plan_deltas);
        m.inc("serve.plan_estimated", st.plan_estimated);
        m.inc("serve.registered", st.registered);
        m.inc("serve.released", st.released);
        m.gauge("serve.plan_hit_rate", st.hit_rate());
        for (client, cs) in &st.per_client {
            m.inc(&format!("serve.client.{client}.requests"), cs.requests);
            m.inc(&format!("serve.client.{client}.hits"), cs.hits);
            m.inc(&format!("serve.client.{client}.misses"), cs.misses);
            m.inc(&format!("serve.client.{client}.deltas"), cs.deltas);
            m.inc(&format!("serve.client.{client}.estimated"), cs.estimated);
        }
        m.observe_store_stats("serve.store", &self.store.stats());
    }

    /// The `stats` protocol op's payload.
    pub fn stats_json(&self) -> Json {
        let st = self.stats();
        let ss = self.store.stats();
        let mut o = Json::obj();
        o.set("requests", (st.requests as i64).into());
        o.set("busy_rejections", (st.busy_rejections as i64).into());
        o.set("plan_hits", (st.plan_hits as i64).into());
        o.set("disk_hits", (st.disk_hits as i64).into());
        o.set("plan_misses", (st.plan_misses as i64).into());
        o.set("plan_deltas", (st.plan_deltas as i64).into());
        o.set("plan_estimated", (st.plan_estimated as i64).into());
        o.set("plan_hit_rate", st.hit_rate().into());
        o.set("registered", (st.registered as i64).into());
        o.set("released", (st.released as i64).into());
        o.set("registered_live", (self.registered_live() as i64).into());
        o.set("queue_depth", (self.queue.depth() as i64).into());
        o.set("queue_capacity", (self.queue.capacity() as i64).into());
        let mut store = Json::obj();
        store.set("mem_hits", (ss.mem_hits as i64).into());
        store.set("disk_hits", (ss.disk_hits as i64).into());
        store.set("misses", (ss.misses as i64).into());
        store.set("stores", (ss.stores as i64).into());
        store.set("evictions", (ss.evictions as i64).into());
        store.set("corrupt", (ss.corrupt as i64).into());
        store.set("stale", (ss.stale as i64).into());
        store.set("delta_patches", (ss.delta_patches as i64).into());
        o.set("store", store);
        let mut clients = Json::obj();
        for (client, cs) in &st.per_client {
            let mut c = Json::obj();
            c.set("requests", (cs.requests as i64).into());
            c.set("hits", (cs.hits as i64).into());
            c.set("misses", (cs.misses as i64).into());
            c.set("deltas", (cs.deltas as i64).into());
            c.set("estimated", (cs.estimated as i64).into());
            clients.set(&client.to_string(), c);
        }
        o.set("clients", clients);
        o
    }
}

/// Holds the worker parked; drop to resume (see
/// [`ServeHandle::quiesce`]).
pub struct QuiesceGuard {
    _release: mpsc::Sender<()>,
}

/// A running daemon core: one shared [`TieredStore`], one worker
/// thread with a resident [`BatchExecutor`] over a clone of it, one
/// bounded queue. The Unix-socket front end is [`session::run_daemon`];
/// in-process consumers use [`Server::handle`] directly.
pub struct Server {
    handle: ServeHandle,
    worker: Option<JoinHandle<()>>,
}

impl Server {
    /// Start with a store built from `cfg.plan_cache` **explicitly** —
    /// never from the process-default `OnceLock` (see
    /// [`resolve_plan_cache`] for why that latch is a footgun under a
    /// daemon).
    pub fn start(cfg: &ServeConfig) -> Server {
        let store = match &cfg.plan_cache {
            Some(dir) => TieredStore::with_disk(dir.clone()),
            None => TieredStore::mem_only(),
        };
        Server::start_with_store(cfg, store)
    }

    /// Start over an existing store handle (tests; embedding the daemon
    /// next to other executors that should pool plans with it).
    pub fn start_with_store(cfg: &ServeConfig, store: TieredStore) -> Server {
        let (queue, jobs) = queue::bounded(cfg.queue_capacity);
        let handle = ServeHandle {
            queue,
            registry: Arc::new(Mutex::new(MatrixRegistry::new())),
            stats: Arc::new(Mutex::new(ServeStats::default())),
            store: store.clone(),
            planner: cfg.planner,
            shutting_down: Arc::new(AtomicBool::new(false)),
            next_client: Arc::new(AtomicU64::new(1)),
        };
        let executor = BatchExecutor::with_store(cfg.n_streams, store);
        let stats = Arc::clone(&handle.stats);
        let worker = std::thread::Builder::new()
            .name("spgemm-serve-worker".into())
            .spawn(move || worker_loop(jobs, executor, stats))
            .expect("spawn serve worker");
        Server { handle, worker: Some(worker) }
    }

    /// A clonable client handle.
    pub fn handle(&self) -> ServeHandle {
        self.handle.clone()
    }

    /// Stop accepting work, drain everything already accepted, join
    /// the worker. (Dropping the server does the same.)
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        let Some(worker) = self.worker.take() else {
            return;
        };
        self.handle.shutting_down.store(true, Ordering::SeqCst);
        // Blocking submit: the shutdown job queues *behind* accepted
        // work, so in-flight clients get their replies before the
        // worker exits.
        let _ = self.handle.queue.submit_blocking(Job::Shutdown);
        let _ = worker.join();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn worker_loop(jobs: QueueReceiver<Job>, mut executor: BatchExecutor, stats: Arc<Mutex<ServeStats>>) {
    while let Some(job) = jobs.recv() {
        match job {
            Job::Multiply { a, b, mask, client, planner, reply } => {
                let (c, trace) = match &mask {
                    None => executor.multiply_cached_policy(&a, &b, planner),
                    Some(m) => executor.multiply_cached_masked_policy(&a, &b, m, planner),
                };
                let checksum = csr_checksum(&c);
                {
                    let mut st = stats.lock().unwrap_or_else(|e| e.into_inner());
                    st.requests += 1;
                    match trace.source {
                        PlanSource::Fresh => st.plan_misses += 1,
                        PlanSource::Disk => st.disk_hits += 1,
                        PlanSource::Mem | PlanSource::Shared => st.plan_hits += 1,
                        PlanSource::Delta => st.plan_deltas += 1,
                        PlanSource::Estimated => st.plan_estimated += 1,
                    }
                    let cs = st.per_client.entry(client).or_default();
                    cs.requests += 1;
                    match trace.source {
                        PlanSource::Delta => cs.deltas += 1,
                        PlanSource::Estimated => cs.estimated += 1,
                        s if s.is_hit() => cs.hits += 1,
                        _ => cs.misses += 1,
                    }
                }
                let outcome = MultiplyOutcome {
                    nnz: trace.nnz,
                    checksum,
                    source: trace.source,
                    plan_s: trace.plan_s,
                    fill_s: trace.fill_s,
                    symbolic_s: trace.symbolic_s,
                    c,
                };
                // The client may have disconnected mid-flight; its
                // result is simply dropped.
                let _ = reply.send(outcome);
            }
            Job::Quiesce { entered, release } => {
                let _ = entered.send(());
                // Park until the guard drops (recv errors when the
                // sender is gone — same thing).
                let _ = release.recv();
            }
            Job::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spgemm::hash;
    use crate::util::Pcg32;

    fn random_square(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg32::seeded(seed);
        crate::gen::rmat(n, n * 4, crate::gen::RmatParams::uniform(), &mut rng)
    }

    fn mem_server(capacity: usize) -> Server {
        Server::start_with_store(
            &ServeConfig { queue_capacity: capacity, n_streams: 2, ..ServeConfig::default() },
            TieredStore::mem_only(),
        )
    }

    #[test]
    fn checksum_separates_structure_and_values() {
        let a = random_square(1, 64);
        let mut a2 = a.clone();
        assert_eq!(csr_checksum(&a), csr_checksum(&a.clone()));
        a2.map_values(|v| v + 1.0);
        assert_ne!(csr_checksum(&a), csr_checksum(&a2), "value changes must change the checksum");
    }

    #[test]
    fn register_multiply_release_roundtrip() {
        let server = mem_server(8);
        let h = server.handle();
        let client = h.new_client();
        let a = random_square(2, 96);
        let reference = hash::multiply(&a, &a);
        let ha = h.register(a).unwrap();
        let out = h.multiply_by_handle(client, ha.raw(), ha.raw()).unwrap();
        assert_eq!(out.source, PlanSource::Fresh);
        assert_eq!(out.c, reference, "served product equals a cold multiply");
        assert_eq!(out.nnz, reference.nnz());
        assert_eq!(out.checksum, csr_checksum(&reference));
        assert!(out.symbolic_s > 0.0);
        // Second multiply: memory hit, zero symbolic seconds, identical.
        let out2 = h.multiply_by_handle(client, ha.raw(), ha.raw()).unwrap();
        assert_eq!(out2.source, PlanSource::Mem);
        assert_eq!(out2.symbolic_s, 0.0);
        assert_eq!(out2.checksum, out.checksum);
        // Release: the handle is dead, with the generation bumped.
        h.release(ha.raw()).unwrap();
        assert!(matches!(h.release(ha.raw()), Err(ServeError::UnknownHandle(_))));
        assert!(matches!(
            h.multiply_by_handle(client, ha.raw(), ha.raw()),
            Err(ServeError::UnknownHandle(_))
        ));
        let st = h.stats();
        assert_eq!((st.requests, st.plan_hits, st.plan_misses), (2, 1, 1));
        assert_eq!((st.registered, st.released), (1, 1));
        assert_eq!(st.per_client.get(&client).unwrap().requests, 2);
        server.shutdown();
    }

    #[test]
    fn shape_mismatch_is_a_bad_request() {
        let server = mem_server(4);
        let h = server.handle();
        let e = h
            .multiply(h.new_client(), Arc::new(Csr::identity(4)), Arc::new(Csr::identity(5)))
            .unwrap_err();
        assert_eq!(e.code(), "bad_request");
    }

    #[test]
    fn shutdown_rejects_new_work_and_drains() {
        let server = mem_server(4);
        let h = server.handle();
        let a = Arc::new(random_square(3, 64));
        h.multiply(h.new_client(), Arc::clone(&a), Arc::clone(&a)).unwrap();
        server.shutdown();
        assert!(matches!(
            h.multiply(h.new_client(), Arc::clone(&a), a),
            Err(ServeError::ShuttingDown | ServeError::WorkerGone)
        ));
        assert!(matches!(h.register(Csr::identity(4)), Err(ServeError::ShuttingDown)));
    }

    /// Per-request estimated policy: a fully-cold one-shot request
    /// speculates (bit-identically), nothing reaches the shared store,
    /// and once an exact plan is cached the same policy rides the hit.
    #[test]
    fn estimated_requests_speculate_cold_and_never_store() {
        let server = mem_server(8);
        let h = server.handle();
        let client = h.new_client();
        let a = Arc::new(random_square(5, 96));
        let out = h.multiply_policy(client, Arc::clone(&a), Arc::clone(&a), Some(PlannerPolicy::Estimated)).unwrap();
        assert_eq!(out.source, PlanSource::Estimated);
        assert_eq!(out.symbolic_s, 0.0, "no exact symbolic phase ran");
        assert_eq!(out.c, hash::multiply(&a, &a), "speculative serve output must be bit-identical");
        assert_eq!(h.store_stats().stores, 0, "speculative plans never enter the shared store");
        // A default-policy request is exact and warms the store...
        let out2 = h.multiply(client, Arc::clone(&a), Arc::clone(&a)).unwrap();
        assert_eq!(out2.source, PlanSource::Fresh);
        assert_eq!(out2.checksum, out.checksum);
        // ...and an estimated request now rides the exact hit.
        let out3 = h.multiply_policy(client, Arc::clone(&a), a, Some(PlannerPolicy::Estimated)).unwrap();
        assert_eq!(out3.source, PlanSource::Mem);
        assert_eq!(out3.checksum, out.checksum);
        let st = h.stats();
        assert_eq!((st.plan_estimated, st.plan_misses, st.plan_hits), (1, 1, 1));
        assert_eq!(st.per_client.get(&client).unwrap().estimated, 1);
        assert_eq!(st.hit_rate(), 0.5, "estimated requests are excluded from the hit rate");
        let js = h.stats_json().render();
        assert!(js.contains("\"plan_estimated\":1"), "{js}");
        server.shutdown();
    }

    /// The wire's `"mask"` handle: a masked request equals the
    /// multiply-then-filter oracle checksum-for-checksum, caches under
    /// its own (masked) plan identity, and a wrong-shape mask is a
    /// `bad_request`, not a worker panic.
    #[test]
    fn masked_requests_serve_filtered_products_under_their_own_plan() {
        let server = mem_server(8);
        let h = server.handle();
        let client = h.new_client();
        let a = random_square(7, 96);
        let oracle = Mask::from_structure(&a).filter(&hash::multiply(&a, &a));
        let ha = h.register(a).unwrap();
        // Warm the unmasked plan first — the masked request below must
        // not be served from it.
        let full = h.multiply_by_handle(client, ha.raw(), ha.raw()).unwrap();
        let out = h
            .multiply_by_handle_masked_policy(client, ha.raw(), ha.raw(), Some(ha.raw()), None)
            .unwrap();
        assert_eq!(out.source, PlanSource::Fresh, "masked identity is distinct from the unmasked plan");
        assert_eq!(out.c, oracle, "masked serve must equal the multiply-then-filter oracle");
        assert_eq!(out.checksum, csr_checksum(&oracle));
        assert_ne!(out.checksum, full.checksum, "this mask strictly shrinks the product");
        // Repeat: the masked plan pooled in the shared store.
        let out2 = h
            .multiply_by_handle_masked_policy(client, ha.raw(), ha.raw(), Some(ha.raw()), None)
            .unwrap();
        assert_eq!(out2.source, PlanSource::Mem);
        assert_eq!(out2.symbolic_s, 0.0);
        assert_eq!(out2.checksum, out.checksum);
        // A wrong-shape mask bounces before the queue.
        let wrong = h.register(Csr::identity(5)).unwrap();
        let e = h
            .multiply_by_handle_masked_policy(client, ha.raw(), ha.raw(), Some(wrong.raw()), None)
            .unwrap_err();
        assert_eq!(e.code(), "bad_request");
        assert!(e.to_string().contains("mask shape mismatch"), "{e}");
        server.shutdown();
    }

    #[test]
    fn resolve_plan_cache_prefers_flag_over_env() {
        assert_eq!(resolve_plan_cache(Some("/a"), Some("/b")), Some(PathBuf::from("/a")));
        assert_eq!(resolve_plan_cache(None, Some("/b")), Some(PathBuf::from("/b")));
        assert_eq!(resolve_plan_cache(Some(""), Some("/b")), Some(PathBuf::from("/b")), "empty flag is unset");
        assert_eq!(resolve_plan_cache(None, Some("")), None, "empty env is unset");
        assert_eq!(resolve_plan_cache(None, None), None);
    }

    #[test]
    fn metrics_and_stats_json_export() {
        let server = mem_server(4);
        let h = server.handle();
        let client = h.new_client();
        let a = Arc::new(random_square(4, 64));
        h.multiply(client, Arc::clone(&a), Arc::clone(&a)).unwrap();
        h.multiply(client, Arc::clone(&a), Arc::clone(&a)).unwrap();
        let mut m = Metrics::new();
        h.export_metrics(&mut m);
        assert_eq!(m.counter("serve.requests"), 2);
        assert_eq!(m.counter("serve.plan_hits"), 1);
        assert_eq!(m.counter("serve.plan_misses"), 1);
        assert_eq!(m.counter(&format!("serve.client.{client}.requests")), 2);
        let js = h.stats_json().render();
        assert!(js.contains("\"requests\":2"), "stats json carries totals: {js}");
        assert!(js.contains("\"plan_hit_rate\":0.5"), "{js}");
        server.shutdown();
    }
}
