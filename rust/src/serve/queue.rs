//! Bounded request queue with explicit backpressure.
//!
//! The daemon's admission contract: the queue never grows without
//! bound. [`RequestQueue::submit`] is a `try_send` — when the channel
//! is at capacity the job comes straight back as
//! [`SubmitError::Busy`], and the protocol layer turns that into a
//! `busy` response the client can retry, instead of the connection
//! thread (and the client behind it) silently parking on a send. The
//! queue depth is tracked explicitly so `serve.queue_depth` is a
//! readable gauge, not something inferred from channel internals.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::Arc;

/// Why a submission was refused — the job is handed back either way.
#[derive(Debug)]
pub enum SubmitError<T> {
    /// Queue at capacity: explicit backpressure, retry later.
    Busy(T),
    /// Receiver gone (worker exited): the queue is permanently closed.
    Closed(T),
}

/// Build a queue of at most `capacity` pending jobs (`capacity >= 1`;
/// a rendezvous channel would make *every* submit "busy" while the
/// worker computes, which is backpressure in name only).
pub fn bounded<T>(capacity: usize) -> (RequestQueue<T>, QueueReceiver<T>) {
    assert!(capacity >= 1, "queue capacity must be at least 1");
    let (tx, rx) = mpsc::sync_channel(capacity);
    let depth = Arc::new(AtomicUsize::new(0));
    (RequestQueue { tx, depth: Arc::clone(&depth), capacity }, QueueReceiver { rx, depth })
}

/// The submitting side. Clones share the channel and the depth gauge
/// (one per connection thread).
pub struct RequestQueue<T> {
    tx: SyncSender<T>,
    depth: Arc<AtomicUsize>,
    capacity: usize,
}

// Manual impl: `T` itself need not be `Clone`.
impl<T> Clone for RequestQueue<T> {
    fn clone(&self) -> RequestQueue<T> {
        RequestQueue { tx: self.tx.clone(), depth: Arc::clone(&self.depth), capacity: self.capacity }
    }
}

impl<T> RequestQueue<T> {
    /// Non-blocking admission: `Ok(depth after enqueue)` or the job
    /// back. The gauge is incremented *before* the send and rolled back
    /// on refusal, so a receiver that drains the job immediately can
    /// never decrement a count that was not yet added.
    pub fn submit(&self, job: T) -> Result<usize, SubmitError<T>> {
        let d = self.depth.fetch_add(1, Ordering::SeqCst) + 1;
        match self.tx.try_send(job) {
            Ok(()) => Ok(d),
            Err(TrySendError::Full(job)) => {
                self.depth.fetch_sub(1, Ordering::SeqCst);
                Err(SubmitError::Busy(job))
            }
            Err(TrySendError::Disconnected(job)) => {
                self.depth.fetch_sub(1, Ordering::SeqCst);
                Err(SubmitError::Closed(job))
            }
        }
    }

    /// Blocking admission — used only for control jobs (shutdown) that
    /// must queue *behind* already-accepted work rather than bounce.
    pub fn submit_blocking(&self, job: T) -> Result<(), SubmitError<T>> {
        self.depth.fetch_add(1, Ordering::SeqCst);
        match self.tx.send(job) {
            Ok(()) => Ok(()),
            Err(mpsc::SendError(job)) => {
                self.depth.fetch_sub(1, Ordering::SeqCst);
                Err(SubmitError::Closed(job))
            }
        }
    }

    /// Jobs currently enqueued (accepted, not yet picked up).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

/// The worker side: exactly one receiver.
pub struct QueueReceiver<T> {
    rx: Receiver<T>,
    depth: Arc<AtomicUsize>,
}

impl<T> QueueReceiver<T> {
    /// Next job, blocking; `None` once every sender is gone.
    pub fn recv(&self) -> Option<T> {
        match self.rx.recv() {
            Ok(job) => {
                self.depth.fetch_sub(1, Ordering::SeqCst);
                Some(job)
            }
            Err(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_recv_tracks_depth() {
        let (q, rx) = bounded::<u32>(4);
        assert_eq!(q.depth(), 0);
        assert_eq!(q.submit(1).unwrap(), 1);
        assert_eq!(q.submit(2).unwrap(), 2);
        assert_eq!(q.depth(), 2);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(q.depth(), 1);
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn full_queue_is_busy_not_blocking() {
        let (q, rx) = bounded::<u32>(2);
        q.submit(1).unwrap();
        q.submit(2).unwrap();
        match q.submit(3) {
            Err(SubmitError::Busy(job)) => assert_eq!(job, 3, "the job must come back"),
            other => panic!("expected Busy, got {other:?}"),
        }
        assert_eq!(q.depth(), 2, "a refused submit must not leak into the gauge");
        // Draining one slot re-admits.
        assert_eq!(rx.recv(), Some(1));
        q.submit(3).unwrap();
    }

    #[test]
    fn dropped_receiver_closes_the_queue() {
        let (q, rx) = bounded::<u32>(2);
        drop(rx);
        assert!(matches!(q.submit(1), Err(SubmitError::Closed(1))));
        assert!(matches!(q.submit_blocking(2), Err(SubmitError::Closed(2))));
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn clones_share_channel_and_gauge() {
        let (q, rx) = bounded::<u32>(3);
        let q2 = q.clone();
        q.submit(1).unwrap();
        q2.submit(2).unwrap();
        assert_eq!(q.depth(), 2);
        assert_eq!(q2.depth(), 2);
        assert_eq!(q2.capacity(), 3);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
    }
}
