//! Unix-socket front end of the daemon: accept loop, per-connection
//! line framing, signal-driven shutdown.
//!
//! [`run_daemon`] binds the socket, starts the [`Server`] core, and
//! accepts connections until either a `shutdown` protocol op or a
//! SIGTERM/SIGINT arrives. All protocol semantics live in
//! [`super::protocol::handle_line`] — this module only moves bytes.
//!
//! Shutdown discipline (what the CI smoke test times): the listener is
//! polled non-blocking (std's blocking `accept` retries `EINTR`
//! internally, so a signal could never interrupt it), connection reads
//! carry a short timeout so every connection thread re-checks the stop
//! flags at a bounded cadence, and the server core drains accepted
//! work before the process exits — a client that got an `ok` submit
//! always gets its reply. The socket file is removed on the way out.

use super::protocol;
use super::{ServeConfig, Server};
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, ErrorKind, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Set by the signal handler; polled by the accept loop and every
/// connection thread.
static SIGNALED: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_signum: i32) {
    SIGNALED.store(true, Ordering::SeqCst);
}

/// Install SIGTERM/SIGINT handlers through libc's `signal` (std links
/// libc on unix; declaring the symbol keeps the crate std-only). The
/// handler only flips an atomic — async-signal-safe by construction.
fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

/// Run the daemon on `socket` until `shutdown` (protocol) or
/// SIGTERM/SIGINT. Blocks the calling thread for the daemon's
/// lifetime.
pub fn run_daemon(socket: &Path, cfg: &ServeConfig) -> Result<()> {
    install_signal_handlers();
    // A stale socket file from a crashed daemon would fail the bind.
    if socket.exists() {
        let _ = std::fs::remove_file(socket);
    }
    if let Some(parent) = socket.parent().filter(|p| !p.as_os_str().is_empty()) {
        let _ = std::fs::create_dir_all(parent);
    }
    let listener =
        UnixListener::bind(socket).with_context(|| format!("binding unix socket {}", socket.display()))?;
    listener.set_nonblocking(true).context("setting the listener non-blocking")?;
    let server = Server::start(cfg);
    let handle = server.handle();
    let stop = Arc::new(AtomicBool::new(false));
    eprintln!(
        "serve: listening on {} (queue capacity {}, {} streams, plan cache {})",
        socket.display(),
        cfg.queue_capacity,
        cfg.n_streams,
        cfg.plan_cache.as_ref().map(|d| d.display().to_string()).unwrap_or_else(|| "none".into()),
    );
    let mut connections = Vec::new();
    while !stop.load(Ordering::SeqCst) && !SIGNALED.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let handle = handle.clone();
                let stop = Arc::clone(&stop);
                connections.push(std::thread::spawn(move || serve_connection(stream, handle, stop)));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(Duration::from_millis(20)),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => {
                eprintln!("serve: accept error: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
    eprintln!("serve: shutting down ({})", if SIGNALED.load(Ordering::SeqCst) { "signal" } else { "protocol" });
    // No new connections; existing ones observe the stop flags within
    // one read timeout, finish their in-flight request (the worker is
    // still up), and exit.
    drop(listener);
    for conn in connections {
        let _ = conn.join();
    }
    // Drain accepted work, join the worker, then clean up the socket.
    server.shutdown();
    let _ = std::fs::remove_file(socket);
    eprintln!("serve: stopped");
    Ok(())
}

/// One connection: read request lines, answer each on its own line.
fn serve_connection(stream: UnixStream, handle: super::ServeHandle, stop: Arc<AtomicBool>) {
    let client = handle.new_client();
    // The read timeout is the connection's stop-poll cadence: idle
    // connections re-check the flags this often, which bounds shutdown
    // latency without a reader thread per flag.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::SeqCst) || SIGNALED.load(Ordering::SeqCst) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client hung up
            Ok(_) => {
                if !line.ends_with('\n') {
                    // Partial final line (EOF without newline): the next
                    // read returns Ok(0) and ends the session.
                    continue;
                }
                let trimmed = line.trim();
                if !trimmed.is_empty() {
                    let (response, stop_daemon) = protocol::handle_line(&handle, client, trimmed);
                    if writeln!(writer, "{response}").and_then(|()| writer.flush()).is_err() {
                        break;
                    }
                    if stop_daemon {
                        stop.store(true, Ordering::SeqCst);
                        break;
                    }
                }
                line.clear();
            }
            // Timeout (flag-poll tick) — partial data read so far stays
            // in `line` and the next pass appends to it.
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
}
