//! Matrix registration handles: upload once, multiply many.
//!
//! A daemon client registers an operand and gets back a [`HandleId`];
//! every later multiply names handles instead of re-shipping (and
//! re-hashing) the matrix. Registration is where
//! [`Csr::structure_hash`] is computed, so the O(nnz) fingerprint scan
//! happens once per upload — every subsequent plan lookup on that
//! operand is a memo read.
//!
//! Handles are **generation-counted**: a slot's generation bumps on
//! release, and a handle carries the generation it was minted under,
//! so a released handle can never alias a matrix that later reuses its
//! slot — resolution fails with "unknown handle" instead of silently
//! multiplying the wrong operand.

use crate::sparse::Csr;
use std::sync::Arc;

/// Opaque client-facing matrix handle: slot index + generation. The
/// wire form is [`HandleId::raw`] (`gen << 32 | index`), which fits the
/// protocol's `i64` JSON integers for any realistic session.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct HandleId {
    pub index: u32,
    pub gen: u32,
}

impl HandleId {
    /// Wire encoding.
    pub fn raw(self) -> u64 {
        (self.gen as u64) << 32 | self.index as u64
    }

    /// Decode a wire handle (any bit pattern decodes; stale or
    /// fabricated handles fail at [`MatrixRegistry::resolve`]).
    pub fn from_raw(raw: u64) -> HandleId {
        HandleId { index: raw as u32, gen: (raw >> 32) as u32 }
    }
}

struct Slot {
    /// Current generation; a handle resolves only while its generation
    /// matches.
    gen: u32,
    entry: Option<Arc<Csr>>,
}

/// Slab of registered matrices with a free list.
#[derive(Default)]
pub struct MatrixRegistry {
    slots: Vec<Slot>,
    free: Vec<u32>,
}

impl MatrixRegistry {
    pub fn new() -> MatrixRegistry {
        MatrixRegistry::default()
    }

    /// Register a matrix, computing (and memoizing) its structure hash
    /// now so multiplies never pay the scan.
    pub fn register(&mut self, m: Arc<Csr>) -> HandleId {
        let _ = m.structure_hash();
        match self.free.pop() {
            Some(index) => {
                let slot = &mut self.slots[index as usize];
                slot.entry = Some(m);
                HandleId { index, gen: slot.gen }
            }
            None => {
                let index = u32::try_from(self.slots.len()).expect("registry slot count exceeds u32");
                self.slots.push(Slot { gen: 0, entry: Some(m) });
                HandleId { index, gen: 0 }
            }
        }
    }

    /// The matrix behind a handle — `None` for released, stale, or
    /// fabricated handles.
    pub fn resolve(&self, h: HandleId) -> Option<Arc<Csr>> {
        self.slots
            .get(h.index as usize)
            .filter(|s| s.gen == h.gen)
            .and_then(|s| s.entry.as_ref().map(Arc::clone))
    }

    /// Release a handle, bumping the slot's generation so the handle
    /// (and any copy of it) is dead forever. `false` if the handle was
    /// already invalid.
    pub fn release(&mut self, h: HandleId) -> bool {
        let Some(slot) = self.slots.get_mut(h.index as usize) else {
            return false;
        };
        if slot.gen != h.gen || slot.entry.is_none() {
            return false;
        }
        slot.entry = None;
        slot.gen = slot.gen.wrapping_add(1);
        self.free.push(h.index);
        true
    }

    /// Registered (live) matrices.
    pub fn len(&self) -> usize {
        self.slots.iter().filter(|s| s.entry.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_roundtrip() {
        let h = HandleId { index: 7, gen: 3 };
        assert_eq!(h.raw(), (3u64 << 32) | 7);
        assert_eq!(HandleId::from_raw(h.raw()), h);
        assert_eq!(HandleId::from_raw(0), HandleId { index: 0, gen: 0 });
    }

    #[test]
    fn register_resolve_release() {
        let mut r = MatrixRegistry::new();
        let a = Arc::new(Csr::identity(4));
        let h = r.register(Arc::clone(&a));
        assert_eq!(r.len(), 1);
        assert!(a.cached_structure_hash().is_some(), "registration must warm the hash memo");
        let got = r.resolve(h).expect("live handle resolves");
        assert!(Arc::ptr_eq(&got, &a));
        assert!(r.release(h));
        assert_eq!(r.len(), 0);
        assert!(r.resolve(h).is_none(), "released handle is dead");
        assert!(!r.release(h), "double release fails");
    }

    #[test]
    fn released_slot_reuse_cannot_alias() {
        let mut r = MatrixRegistry::new();
        let h1 = r.register(Arc::new(Csr::identity(4)));
        assert!(r.release(h1));
        // The slot is reused, but under a bumped generation: the old
        // handle must not resolve to the new matrix.
        let h2 = r.register(Arc::new(Csr::identity(8)));
        assert_eq!(h2.index, h1.index, "free list reuses the slot");
        assert_ne!(h2.gen, h1.gen);
        assert_ne!(h2.raw(), h1.raw());
        assert!(r.resolve(h1).is_none(), "stale handle must not alias the new matrix");
        assert_eq!(r.resolve(h2).unwrap().n_rows, 8);
    }

    #[test]
    fn fabricated_handles_fail() {
        let mut r = MatrixRegistry::new();
        let h = r.register(Arc::new(Csr::identity(2)));
        assert!(r.resolve(HandleId { index: 99, gen: 0 }).is_none());
        assert!(r.resolve(HandleId { index: h.index, gen: h.gen.wrapping_add(5) }).is_none());
        assert!(!r.release(HandleId { index: 99, gen: 0 }));
    }
}
