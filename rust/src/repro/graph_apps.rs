//! Graph-application experiments (Fig. 7: AIA vs software-only, Fig. 8:
//! AIA vs cuSPARSE) — Graph Contraction and Markov Clustering over the
//! six datasets the paper evaluates.

use super::{quick, reduction_pct, save_json, Table, SEED};
use crate::apps::{contract, mcl, random_labels, MclParams};
use crate::coordinator::executor::{SpgemmExecutor, Variant};
use crate::util::json::Json;
use crate::util::Pcg32;

/// The six datasets of Figs. 7–8, in paper order.
pub const GRAPH_APP_DATASETS: [&str; 6] =
    ["RoadTX", "web-Google", "Protein", "Economics", "amazon0601", "WindTunnel"];

fn app_times(name: &str) -> (f64, f64, f64, f64, f64, f64) {
    let ds = crate::gen::table2_by_name(name).unwrap();
    let g = (ds.gen)(SEED);
    let mut rng = Pcg32::new(SEED, 400);
    let labels = random_labels(g.n_rows, (g.n_rows / 4).max(1), &mut rng);
    let mcl_params = MclParams { max_iters: if quick() { 2 } else { 4 }, tol: 1e-4, top_k: 16, ..Default::default() };

    let run = |variant: Variant| -> (f64, f64) {
        let mut ex = SpgemmExecutor::simulated_scaled(variant, ds.scale);
        let c = contract(&g, &labels, &mut ex).sim_ms;
        let mut ex2 = SpgemmExecutor::simulated_scaled(variant, ds.scale);
        let m = mcl(&g, &mcl_params, &mut ex2).sim_ms;
        (c, m)
    };
    let (c_aia, m_aia) = run(Variant::HashAia);
    let (c_sw, m_sw) = run(Variant::Hash);
    let (c_esc, m_esc) = run(Variant::Cusparse);
    (c_aia, c_sw, c_esc, m_aia, m_sw, m_esc)
}

/// Figs. 7 and 8 share the same runs; emit both tables at once.
pub fn fig7_fig8() -> Json {
    println!("\n=== Fig 7/8: Graph Contraction & MCL time reduction ===");
    let t = Table::new(&[13, 12, 12, 12, 12, 10, 10]);
    t.header(&[
        "dataset",
        "GC vs SW",
        "GC vs ESC",
        "MCL vs SW",
        "MCL vs ESC",
        "GC ms",
        "MCL ms",
    ]);
    let datasets: Vec<&str> = if quick() { vec!["Economics", "RoadTX"] } else { GRAPH_APP_DATASETS.to_vec() };
    let mut out = Json::Arr(vec![]);
    let mut gc_sw = Vec::new();
    let mut gc_esc = Vec::new();
    let mut mcl_sw = Vec::new();
    let mut mcl_esc = Vec::new();
    for name in datasets {
        let (c_aia, c_sw, c_esc, m_aia, m_sw, m_esc) = app_times(name);
        let r = [
            reduction_pct(c_sw, c_aia),
            reduction_pct(c_esc, c_aia),
            reduction_pct(m_sw, m_aia),
            reduction_pct(m_esc, m_aia),
        ];
        gc_sw.push(r[0]);
        gc_esc.push(r[1]);
        mcl_sw.push(r[2]);
        mcl_esc.push(r[3]);
        t.row(&[
            name.to_string(),
            format!("{:.1}%", r[0]),
            format!("{:.1}%", r[1]),
            format!("{:.1}%", r[2]),
            format!("{:.1}%", r[3]),
            format!("{c_aia:.1}"),
            format!("{m_aia:.1}"),
        ]);
        let mut o = Json::obj();
        o.set("name", name.into());
        o.set("contraction_ms", Json::Arr(vec![c_aia.into(), c_sw.into(), c_esc.into()]));
        o.set("mcl_ms", Json::Arr(vec![m_aia.into(), m_sw.into(), m_esc.into()]));
        o.set("gc_vs_sw_pct", r[0].into());
        o.set("gc_vs_esc_pct", r[1].into());
        o.set("mcl_vs_sw_pct", r[2].into());
        o.set("mcl_vs_esc_pct", r[3].into());
        out.push(o);
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "\nFig 7 averages (vs software-only): contraction {:.1}% (paper: 4.1-17.3%), MCL {:.1}% (paper: 5.0-13.8%)",
        avg(&gc_sw),
        avg(&mcl_sw)
    );
    println!(
        "Fig 8 averages (vs cuSPARSE): contraction {:.1}% (paper avg: 76.5%), MCL {:.1}% (paper avg: 58.4%)",
        avg(&gc_esc),
        avg(&mcl_esc)
    );
    save_json("fig7_fig8", &out);
    out
}
