//! Experiment harness: one function per paper table/figure (DESIGN.md
//! §4 experiment index). Each prints the paper-style series and returns
//! JSON rows for `EXPERIMENTS.md` and the bench artifacts.

pub mod attention;
pub mod gnn_experiments;
pub mod graph_apps;
pub mod selfproduct;

use crate::util::json::Json;

pub use attention::attention;
pub use gnn_experiments::{fig10_fig11, fig9, table3};
pub use graph_apps::{fig7_fig8, GRAPH_APP_DATASETS};
pub use selfproduct::{fig5, fig6, plan_reuse, table2};

/// Default seed for every experiment (reproducible end to end).
pub const SEED: u64 = 20250710;

/// Quick mode (env `REPRO_QUICK=1`): fewer datasets / epochs, for CI.
pub fn quick() -> bool {
    std::env::var("REPRO_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Pearson correlation coefficient (Fig. 9's r).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let vx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let vy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Percentage reduction from `base` to `new` (paper's "time reduction").
pub fn reduction_pct(base: f64, new: f64) -> f64 {
    if base <= 0.0 {
        return 0.0;
    }
    100.0 * (base - new) / base
}

/// Write an experiment's JSON to `target/repro/<name>.json`.
pub fn save_json(name: &str, json: &Json) {
    let dir = std::path::Path::new("target/repro");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{name}.json"));
    if std::fs::write(&path, json.render_pretty()).is_ok() {
        println!("[saved {}]", path.display());
    }
}

/// Simple fixed-width table printer.
pub struct Table {
    pub widths: Vec<usize>,
}

impl Table {
    pub fn new(widths: &[usize]) -> Table {
        Table { widths: widths.to_vec() }
    }
    pub fn row(&self, cells: &[String]) {
        let line: Vec<String> = cells
            .iter()
            .zip(&self.widths)
            .map(|(c, &w)| format!("{c:>w$}"))
            .collect();
        println!("{}", line.join("  "));
    }
    pub fn header(&self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
        let total: usize = self.widths.iter().sum::<usize>() + 2 * (self.widths.len() - 1);
        println!("{}", "-".repeat(total));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_basics() {
        assert!((pearson(&[1.0, 2.0, 3.0], &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&[1.0, 2.0, 3.0], &[6.0, 4.0, 2.0]) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&[1.0], &[1.0]), 0.0);
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0);
    }

    #[test]
    fn reduction_pct_basics() {
        assert!((reduction_pct(10.0, 5.0) - 50.0).abs() < 1e-12);
        assert!((reduction_pct(10.0, 12.0) + 20.0).abs() < 1e-12);
        assert_eq!(reduction_pct(0.0, 5.0), 0.0);
    }
}
