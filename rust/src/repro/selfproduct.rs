//! Matrix self-product experiments: Table II, Fig. 5 (cache hit
//! ratios), Fig. 6 (runtime + GFLOPS vs cuSPARSE), plus the plan-reuse
//! report for iterative workloads (cold plan+fill vs reused fill, batch
//! pipelining, MCL plan-hit rate).

use super::{quick, reduction_pct, save_json, Table, SEED};
use crate::apps::{mcl, MclParams};
use crate::coordinator::batch::BatchExecutor;
use crate::coordinator::executor::{SpgemmExecutor, Variant};
use crate::gen::{table2_datasets, Dataset};
use crate::sim::probe::Phase;
use crate::sim::{gflops, simulate_stats, AiaMode, SimConfig};
use crate::spgemm::hash::{PlannedProduct, TieredStore};
use crate::spgemm::{hash, ip, Algo};
use crate::util::json::Json;

fn active_datasets() -> Vec<Dataset> {
    let all = table2_datasets();
    if quick() {
        all.into_iter().filter(|d| ["scircuit", "Economics", "p2p-Gnutella04"].contains(&d.paper.name)).collect()
    } else {
        all
    }
}

/// Table II: generated-analogue characteristics vs the paper's.
pub fn table2() -> Json {
    println!("\n=== Table II: matrix data (synthetic analogues vs paper) ===");
    let t = Table::new(&[15, 10, 11, 8, 8, 14, 12, 7]);
    t.header(&["name", "rows", "nnz", "nnz/row", "max/row", "IP(A^2)", "nnz(A^2)", "scale"]);
    let mut out = Json::Arr(vec![]);
    for ds in active_datasets() {
        let a = (ds.gen)(SEED);
        let s = crate::sparse::MatrixStats::of(&a);
        let total_ip = ip::total_ip(&a, &a);
        let c = hash::multiply(&a, &a);
        t.row(&[
            ds.paper.name.to_string(),
            s.rows.to_string(),
            s.nnz.to_string(),
            format!("{:.1}", s.avg_nnz_row),
            s.max_nnz_row.to_string(),
            total_ip.to_string(),
            c.nnz().to_string(),
            format!("1/{}", ds.scale),
        ]);
        let mut o = Json::obj();
        o.set("name", ds.paper.name.into());
        o.set("rows", s.rows.into());
        o.set("nnz", s.nnz.into());
        o.set("nnz_per_row", s.avg_nnz_row.into());
        o.set("max_nnz_row", s.max_nnz_row.into());
        o.set("ip_a2", (total_ip as i64).into());
        o.set("nnz_a2", c.nnz().into());
        o.set("paper_rows", ds.paper.rows.into());
        o.set("paper_nnz", ds.paper.nnz.into());
        o.set("paper_ip_a2", (ds.paper.ip_a2 as i64).into());
        o.set("paper_nnz_a2", (ds.paper.nnz_a2 as i64).into());
        out.push(o);
    }
    save_json("table2", &out);
    out
}

/// Fig. 5: L1 hit ratio ±AIA in the allocation and accumulation phases,
/// for scircuit and cage15 (paper: scircuit 64.66→88.15 alloc,
/// 64.41→75.14 accum; cage15 64.01→84.10 alloc, 35.94→50.02 accum).
pub fn fig5() -> Json {
    println!("\n=== Fig 5: L1 cache hit ratio (hash SpGEMM, A^2) ===");
    let t = Table::new(&[15, 13, 13, 13, 13]);
    t.header(&["dataset", "alloc noAIA", "alloc AIA", "accum noAIA", "accum AIA"]);
    let mut out = Json::Arr(vec![]);
    let paper: &[(&str, [f64; 4])] = &[
        ("scircuit", [64.66, 88.15, 64.41, 75.14]),
        ("cage15", [64.01, 84.10, 35.94, 50.02]),
    ];
    for (name, paper_vals) in paper {
        let ds = crate::gen::table2_by_name(name).unwrap();
        let a = (ds.gen)(SEED);
        let off = simulate_stats(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::Off, ds.scale));
        let on = simulate_stats(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::On, ds.scale));
        let g = |r: &crate::sim::SimReport, p: Phase| r.phase(p).map(|x| 100.0 * x.l1_hit_ratio).unwrap_or(0.0);
        let vals = [
            g(&off, Phase::Allocation),
            g(&on, Phase::Allocation),
            g(&off, Phase::Accumulation),
            g(&on, Phase::Accumulation),
        ];
        t.row(&[
            name.to_string(),
            format!("{:.2}%", vals[0]),
            format!("{:.2}%", vals[1]),
            format!("{:.2}%", vals[2]),
            format!("{:.2}%", vals[3]),
        ]);
        println!(
            "  paper:        {:>10.2}% {:>12.2}% {:>12.2}% {:>12.2}%",
            paper_vals[0], paper_vals[1], paper_vals[2], paper_vals[3]
        );
        let mut o = Json::obj();
        o.set("name", (*name).into());
        o.set("alloc_noaia", vals[0].into());
        o.set("alloc_aia", vals[1].into());
        o.set("accum_noaia", vals[2].into());
        o.set("accum_aia", vals[3].into());
        o.set("paper", Json::Arr(paper_vals.iter().map(|&v| Json::Num(v)).collect()));
        out.push(o);
    }
    save_json("fig5", &out);
    out
}

/// Plan reuse on the iterative self-product workload (the MCL/GNN
/// execution pattern): per dataset, the cost of a cold plan+fill vs a
/// reused numeric fill, the accumulator selection the plan baked in
/// (copy/hash/SPA row split), and the per-bin overlap won by pipelining
/// a batch of fills through [`BatchExecutor`] (bins dispatched as
/// completion events, fill seconds split per accumulator kind); then
/// the plan-hit rate of a real MCL run, where the flow structure
/// stabilises as clustering converges; the estimated-planner crossover
/// on one-shot products; and the byte-accurate line-utilization table
/// of the traced runs ±AIA.
pub fn plan_reuse() -> Json {
    println!("\n=== Plan reuse: amortizing symbolic analysis across numeric fills (A^2) ===");
    let t = Table::new(&[15, 11, 11, 11, 9, 10, 6, 15, 15, 12]);
    t.header(&[
        "name",
        "plan ms",
        "fill ms",
        "cold ms",
        "reuse",
        "overlap",
        "bins",
        "rows c/h/s",
        "sym t/h/b",
        "sym ms h/b",
    ]);
    let mut out = Json::obj();
    let mut rows = Json::Arr(vec![]);
    for ds in active_datasets() {
        let a = (ds.gen)(SEED);
        let p = PlannedProduct::plan(&a, &a);
        let plan_s = p.plan_times.total_s();
        let (_, fill_times) = p.fill_timed(&a, &a);
        let fill_s = fill_times.numeric_s;
        let cold_s = plan_s + fill_s;
        let reuse_x = cold_s / fill_s.max(1e-12);
        let kind_rows = p.symbolic_plan().kind_rows();
        // The symbolic counterpart of the numeric split: which counting
        // kernel sized each row, and what each kernel cost at plan time.
        let sym_rows = p.symbolic_plan().symbolic_kind_rows();
        let sym_s = p.plan_times.symbolic_kind_s;
        // Pipelined batch of 4 structurally *distinct* products (repeated
        // structures would be deduped to one plan): the planner emits
        // per-bin completion events, so symbolic analysis of product k+1
        // overlaps the individual bin fills of product k. Memory-only
        // store, so the overlap metric stays an overlap metric even when
        // `--plan-cache` is set (the disk tier gets its own section
        // below).
        let variants: Vec<_> = (0..4u64).map(|k| (ds.gen)(SEED + k)).collect();
        let pairs: Vec<_> = variants.iter().map(|m| (m, m)).collect();
        let mut bx = BatchExecutor::with_store(4, TieredStore::mem_only());
        bx.execute_batch(&pairs);
        let report = bx.last_batch.as_ref().expect("batch ran");
        let overlap_x = report.overlap_speedup();
        t.row(&[
            ds.paper.name.to_string(),
            format!("{:.2}", plan_s * 1e3),
            format!("{:.2}", fill_s * 1e3),
            format!("{:.2}", cold_s * 1e3),
            format!("{reuse_x:.2}x"),
            format!("{overlap_x:.2}x"),
            report.bins.to_string(),
            format!("{}/{}/{}", kind_rows[0], kind_rows[1], kind_rows[2]),
            format!("{}/{}/{}", sym_rows[0], sym_rows[1], sym_rows[2]),
            format!("{:.2}/{:.2}", sym_s[1] * 1e3, sym_s[2] * 1e3),
        ]);
        let mut o = Json::obj();
        o.set("name", ds.paper.name.into());
        o.set("plan_ms", (plan_s * 1e3).into());
        o.set("fill_ms", (fill_s * 1e3).into());
        o.set("cold_ms", (cold_s * 1e3).into());
        o.set("reuse_speedup", reuse_x.into());
        o.set("batch_overlap_speedup", overlap_x.into());
        o.set("stream_utilization", report.streams.utilization().into());
        // Per-bin overlap metrics: dispatch units and the per-kind
        // numeric split of the pipelined fills.
        o.set("batch_bins", report.bins.into());
        o.set("copy_rows", kind_rows[0].into());
        o.set("hash_rows", kind_rows[1].into());
        o.set("spa_rows", kind_rows[2].into());
        o.set("fill_copy_ms", (report.fill_kind_s[0] * 1e3).into());
        o.set("fill_hash_ms", (report.fill_kind_s[1] * 1e3).into());
        o.set("fill_spa_ms", (report.fill_kind_s[2] * 1e3).into());
        // Symbolic per-kind split: rows counted by each kernel and the
        // plan-time seconds each kernel spent.
        o.set("symbolic_trivial_rows", sym_rows[0].into());
        o.set("symbolic_hash_rows", sym_rows[1].into());
        o.set("symbolic_bitmap_rows", sym_rows[2].into());
        o.set("symbolic_trivial_ms", (sym_s[0] * 1e3).into());
        o.set("symbolic_hash_ms", (sym_s[1] * 1e3).into());
        o.set("symbolic_bitmap_ms", (sym_s[2] * 1e3).into());
        rows.push(o);
    }
    out.set("rows", rows);
    // Disk-tier persistence: the same product planned (and persisted)
    // by one executor, then served to a *fresh* executor whose memory
    // tier is cold — the cross-process reuse `--plan-cache` enables.
    // Uses the configured plan-cache dir when one is set (so repeated
    // `repro planreuse` runs demonstrate real cross-process hits), a
    // scratch dir under the target tree otherwise.
    let cache_dir = hash::default_plan_cache_dir()
        .unwrap_or_else(|| std::path::PathBuf::from("target/repro/plan-cache"));
    let ds = crate::gen::table2_by_name("Economics").unwrap();
    let a = (ds.gen)(SEED);
    let cold_c = hash::multiply(&a, &a);
    let mut writer = BatchExecutor::with_store(4, TieredStore::with_disk(&cache_dir));
    writer.multiply_cached(&a, &a); // plans (or disk-hits a previous run) and persists
    let mut reader = BatchExecutor::with_store(4, TieredStore::with_disk(&cache_dir));
    let c = reader.multiply_cached(&a, &a); // cold memory tier: load + validate + fill
    let bit_identical = c == cold_c;
    println!(
        "\nDisk tier ({}): Economics A^2 served to a cold process — disk hits {} / plans built {}, \
         load+validate {:.2} ms, fill {:.2} ms, 0 symbolic ms on the hit path, bit-identical to cold multiply: {}",
        cache_dir.display(),
        reader.stats.disk_hits,
        reader.stats.plans_built,
        reader.stats.plan_s * 1e3,
        reader.stats.fill_s * 1e3,
        bit_identical
    );
    let ss = reader.store_stats();
    let mut disk = Json::obj();
    disk.set("dir", cache_dir.display().to_string().into());
    disk.set("disk_hits", reader.stats.disk_hits.into());
    disk.set("plans_built", reader.stats.plans_built.into());
    disk.set("load_validate_ms", (reader.stats.plan_s * 1e3).into());
    disk.set("fill_ms", (reader.stats.fill_s * 1e3).into());
    disk.set("bit_identical", bit_identical.into());
    disk.set("store_corrupt", (ss.corrupt as i64).into());
    disk.set("store_stale", (ss.stale as i64).into());
    disk.set("store_evictions", (ss.evictions as i64).into());
    out.set("disk", disk);
    // Incremental replanning under structural drift (the dynamic-graph
    // path): mutate 1% of the rows of A and delta-patch the existing
    // plan instead of replanning cold — the symbolic phase re-runs only
    // for the dirty rows, and the patched plan is bit-identical to a
    // cold plan of the mutated product.
    let ds = crate::gen::table2_by_name("Economics").unwrap();
    let a = (ds.gen)(SEED);
    let base = PlannedProduct::plan(&a, &a);
    let cold_plan_s = base.plan_times.total_s();
    let cold_symbolic_s = base.plan_times.symbolic_s;
    let a2 = hash::mutate_row_fraction(&a, 0.01, SEED);
    let mut delta = Json::obj();
    match hash::delta_patch(&base, &a2, &a, &hash::EngineConfig::default()) {
        hash::DeltaOutcome::Patched(dp) => {
            let delta_plan_s = dp.plan.plan_times.total_s();
            let delta_symbolic_s = dp.plan.plan_times.symbolic_s;
            let (c_delta, _) = dp.plan.fill_timed(&a2, &a);
            let bit_identical = c_delta == hash::multiply(&a2, &a);
            println!(
                "\nDelta replan (Economics, 1% rows dirty): {} / {} rows re-planned — plan {:.2} ms cold vs {:.2} ms \
                 delta, symbolic {:.2} ms cold vs {:.2} ms delta, bit-identical to cold multiply: {}",
                dp.dirty_rows,
                a.n_rows,
                cold_plan_s * 1e3,
                delta_plan_s * 1e3,
                cold_symbolic_s * 1e3,
                delta_symbolic_s * 1e3,
                bit_identical
            );
            delta.set("dirty_rows", dp.dirty_rows.into());
            delta.set("total_rows", a.n_rows.into());
            delta.set("delta_rows", dp.dirty_rows.into());
            delta.set("cold_plan_ms", (cold_plan_s * 1e3).into());
            delta.set("delta_plan_ms", (delta_plan_s * 1e3).into());
            delta.set("cold_symbolic_ms", (cold_symbolic_s * 1e3).into());
            delta.set("delta_symbolic_ms", (delta_symbolic_s * 1e3).into());
            delta.set("bit_identical", bit_identical.into());
        }
        hash::DeltaOutcome::Rebuild(why) => {
            println!("\nDelta replan (Economics): fell back to full replan ({why})");
            delta.set("rebuild", why.into());
        }
    }
    out.set("delta", delta);
    // Plan-hit rate of an actual MCL run: early iterations replan as
    // pruning reshapes the flow (delta-patched where the drift is
    // bounded), late iterations reuse.
    let ds = crate::gen::table2_by_name("Economics").unwrap();
    let g = (ds.gen)(SEED);
    let mut ex = SpgemmExecutor::fast(Variant::Hash);
    let iters = if quick() { 4 } else { 8 };
    let r = mcl(&g, &MclParams { max_iters: iters, tol: 1e-4, top_k: 16, ..Default::default() }, &mut ex);
    let expansions = (r.plan_hits + r.disk_hits + r.plan_deltas + r.plan_misses).max(1);
    let hit_rate = (r.plan_hits + r.disk_hits) as f64 / expansions as f64;
    println!(
        "\nMCL(Economics, {} iters): {} plan hits ({} from disk) / {} delta patches ({} rows re-planned) / {} full \
         misses — {:.0}% of expansions skipped the symbolic phase entirely",
        r.iterations,
        r.plan_hits + r.disk_hits,
        r.disk_hits,
        r.plan_deltas,
        r.delta_rows,
        r.plan_misses,
        100.0 * hit_rate
    );
    out.set("mcl_iterations", r.iterations.into());
    out.set("mcl_plan_hits", r.plan_hits.into());
    out.set("mcl_plan_misses", r.plan_misses.into());
    out.set("mcl_disk_hits", r.disk_hits.into());
    out.set("mcl_plan_deltas", r.plan_deltas.into());
    out.set("mcl_delta_rows", r.delta_rows.into());
    out.set("mcl_plan_hit_rate", hit_rate.into());
    // Estimated-plan crossover (the one-shot product path, DESIGN.md
    // §2g): the exact pipeline counts every row before sizing, the
    // estimated planner samples ~2% of rows, extrapolates the
    // IP-weighted bound, and lets the numeric phase grow-and-retry the
    // rows it undersized. Speculation pays exactly when the plan is
    // used once — sampling saves per product, fallback costs only on
    // underestimated rows — and output is bit-identical either way, so
    // the crossover variable is time alone.
    println!("\nEstimated planner crossover (one-shot A^2): exact plan+fill vs sampled plan + fallback ladder");
    let te = Table::new(&[15, 11, 11, 9, 12, 14, 7]);
    te.header(&["name", "exact ms", "est ms", "speedup", "estimate ms", "fallback rows", "ident"]);
    let mut est_rows = Json::Arr(vec![]);
    for ds in active_datasets() {
        let a = (ds.gen)(SEED);
        let t0 = std::time::Instant::now();
        let c_exact = hash::multiply(&a, &a);
        let exact_s = t0.elapsed().as_secs_f64();
        let (c_est, rep) = hash::multiply_estimated(&a, &a);
        let est_s = rep.estimate_s + rep.numeric_s;
        let bit_identical = c_est == c_exact;
        let fallback_rate = rep.fallback_rows as f64 / a.n_rows.max(1) as f64;
        te.row(&[
            ds.paper.name.to_string(),
            format!("{:.2}", exact_s * 1e3),
            format!("{:.2}", est_s * 1e3),
            format!("{:.2}x", exact_s / est_s.max(1e-12)),
            format!("{:.2}", rep.estimate_s * 1e3),
            format!("{} ({:.1}%)", rep.fallback_rows, 100.0 * fallback_rate),
            bit_identical.to_string(),
        ]);
        let mut o = Json::obj();
        o.set("name", ds.paper.name.into());
        o.set("exact_ms", (exact_s * 1e3).into());
        o.set("estimated_ms", (est_s * 1e3).into());
        o.set("speedup", (exact_s / est_s.max(1e-12)).into());
        o.set("estimate_ms", (rep.estimate_s * 1e3).into());
        o.set("numeric_ms", (rep.numeric_s * 1e3).into());
        o.set("sampled_rows", rep.sampled_rows.into());
        o.set("estimated_nnz", rep.estimated_nnz.into());
        o.set("nnz", rep.nnz.into());
        o.set("fallback_rows", rep.fallback_rows.into());
        o.set("fallback_rate", fallback_rate.into());
        o.set("bit_identical", bit_identical.into());
        est_rows.push(o);
    }
    out.set("estimated", est_rows);
    // Byte-accurate line utilization of the traced A^2 runs, ±AIA: of
    // every HBM line fetched, how many bytes were actually consumed
    // before eviction. The paper's central claim in one table — AIA
    // turns the gather's wasted line fills into consumed stream bytes.
    println!("\nLine utilization (traced A^2, hash engine): bytes touched vs bytes fetched from HBM");
    let tw = Table::new(&[15, 12, 11, 11, 11, 20]);
    tw.header(&["name", "fetched MB", "used MB", "waste off", "waste on", "top waster (off)"]);
    let mut waste_rows = Json::Arr(vec![]);
    for ds in active_datasets() {
        let a = (ds.gen)(SEED);
        let off = simulate_stats(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::Off, ds.scale));
        let on = simulate_stats(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::On, ds.scale));
        let top = off.region_waste().into_iter().max_by_key(|r| r.fetched_bytes - r.used_bytes);
        let top_label = top
            .as_ref()
            .map(|r| format!("{} ({:.0}% waste)", r.region.name(), 100.0 * r.waste_ratio()))
            .unwrap_or_else(|| "-".into());
        tw.row(&[
            ds.paper.name.to_string(),
            format!("{:.2}", off.fetched_bytes() as f64 / 1e6),
            format!("{:.2}", off.used_bytes() as f64 / 1e6),
            format!("{:.1}%", 100.0 * off.waste_ratio()),
            format!("{:.1}%", 100.0 * on.waste_ratio()),
            top_label,
        ]);
        let mut o = Json::obj();
        o.set("name", ds.paper.name.into());
        o.set("used_bytes_off", (off.used_bytes() as i64).into());
        o.set("fetched_bytes_off", (off.fetched_bytes() as i64).into());
        o.set("waste_off", off.waste_ratio().into());
        o.set("used_bytes_on", (on.used_bytes() as i64).into());
        o.set("fetched_bytes_on", (on.fetched_bytes() as i64).into());
        o.set("waste_on", on.waste_ratio().into());
        let mut regions = Json::Arr(vec![]);
        for r in off.region_waste() {
            let mut ro = Json::obj();
            ro.set("region", r.region.name().into());
            ro.set("used_bytes", (r.used_bytes as i64).into());
            ro.set("fetched_bytes", (r.fetched_bytes as i64).into());
            regions.push(ro);
        }
        o.set("regions_off", regions);
        waste_rows.push(o);
    }
    out.set("waste", waste_rows);
    save_json("plan_reuse", &out);
    out
}

/// Fig. 6: runtime and GFLOPS of A² for hash+AIA / hash / ESC-cuSPARSE.
pub fn fig6() -> Json {
    println!("\n=== Fig 6: self-product runtime & GFLOPS (simulated H200) ===");
    let t = Table::new(&[15, 10, 10, 10, 9, 9, 10, 10]);
    t.header(&["name", "AIA ms", "noAIA ms", "ESC ms", "AIAvsESC", "AIAvsSW", "AIA GF/s", "ESC GF/s"]);
    let mut out = Json::Arr(vec![]);
    let mut red_esc = Vec::new();
    let mut red_sw = Vec::new();
    let mut speedup_gf = Vec::new();
    for ds in active_datasets() {
        let a = (ds.gen)(SEED);
        let total_ip = ip::total_ip(&a, &a);
        let on = simulate_stats(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::On, ds.scale)).total_ms;
        let off = simulate_stats(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::Off, ds.scale)).total_ms;
        let esc = simulate_stats(Algo::Esc, &a, &a, &SimConfig::for_scale(AiaMode::Off, ds.scale)).total_ms;
        let (gf_on, gf_esc) = (gflops(total_ip, on), gflops(total_ip, esc));
        red_esc.push(reduction_pct(esc, on));
        red_sw.push(reduction_pct(off, on));
        speedup_gf.push(gf_on / gf_esc.max(1e-12));
        t.row(&[
            ds.paper.name.to_string(),
            format!("{on:.2}"),
            format!("{off:.2}"),
            format!("{esc:.2}"),
            format!("{:.1}%", reduction_pct(esc, on)),
            format!("{:.1}%", reduction_pct(off, on)),
            format!("{gf_on:.1}"),
            format!("{gf_esc:.1}"),
        ]);
        let mut o = Json::obj();
        o.set("name", ds.paper.name.into());
        o.set("aia_ms", on.into());
        o.set("noaia_ms", off.into());
        o.set("esc_ms", esc.into());
        o.set("ip", (total_ip as i64).into());
        o.set("gflops_aia", gf_on.into());
        o.set("gflops_esc", gf_esc.into());
        out.push(o);
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "\naverage runtime reduction: AIA vs cuSPARSE(ESC) {:.1}% (paper: 80.5%), AIA vs software-only {:.1}% (paper: 10-27%)",
        avg(&red_esc),
        avg(&red_sw)
    );
    println!("average GFLOPS speedup over cuSPARSE: {:.2}x (paper: 6.87x)", avg(&speedup_gf));
    save_json("fig6", &out);
    out
}
