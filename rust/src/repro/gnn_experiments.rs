//! GNN experiments: Table III (dataset characteristics), Fig. 9 (SpGEMM
//! AIA reduction vs graph size + Pearson r), Figs. 10–11 (training-time
//! reduction with AIA vs software-only and vs cuSPARSE).

use super::{pearson, quick, reduction_pct, save_json, Table, SEED};
use crate::coordinator::executor::{SpgemmExecutor, Variant};
use crate::gen::table3_datasets;
use crate::gnn::{sparsify, Arch, GnnData, Trainer, TOPK};
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::error::Result;

/// Shared cache-scaling factor for every GNN simulation (the datasets
/// are all scaled into the same node-count tier band, so they see one
/// device; Fig. 9's size-scaling then emerges from working-set growth).
pub const GNN_SIM_SCALE: usize = 16;

fn active() -> Vec<crate::gen::GnnDataset> {
    let all = table3_datasets();
    if quick() {
        all.into_iter().filter(|d| ["Flickr", "ogbn-arxiv"].contains(&d.paper.name)).collect()
    } else {
        all
    }
}

/// Table III: GNN dataset analogues vs paper characteristics.
pub fn table3() -> Json {
    println!("\n=== Table III: GNN dataset characteristics ===");
    let t = Table::new(&[15, 9, 11, 10, 11, 12, 12]);
    t.header(&["dataset", "nodes", "edges", "avg deg", "density %", "paper nodes", "paper deg"]);
    let mut out = Json::Arr(vec![]);
    for ds in active() {
        let a = (ds.gen)(SEED);
        let s = crate::sparse::MatrixStats::of(&a);
        t.row(&[
            ds.paper.name.to_string(),
            s.rows.to_string(),
            s.nnz.to_string(),
            format!("{:.1}", s.avg_nnz_row),
            format!("{:.4}", s.density_pct),
            ds.paper.nodes.to_string(),
            format!("{:.1}", ds.paper.avg_degree),
        ]);
        let mut o = Json::obj();
        o.set("name", ds.paper.name.into());
        o.set("nodes", s.rows.into());
        o.set("edges", s.nnz.into());
        o.set("avg_degree", s.avg_nnz_row.into());
        o.set("density_pct", s.density_pct.into());
        o.set("paper_nodes", ds.paper.nodes.into());
        o.set("paper_avg_degree", ds.paper.avg_degree.into());
        out.push(o);
    }
    save_json("table3", &out);
    out
}

/// Fig. 9: AIA time reduction on the GNN SpGEMM op (Â · TopK(X)) vs
/// graph size; the paper reports r = 0.94 and 15.3%→89.2% scaling.
pub fn fig9() -> Json {
    println!("\n=== Fig 9: SpGEMM AIA time reduction vs graph size ===");
    let t = Table::new(&[15, 9, 11, 12, 12, 12]);
    t.header(&["dataset", "nodes", "edges", "noAIA ms", "AIA ms", "reduction"]);
    let mut out = Json::Arr(vec![]);
    let mut sizes = Vec::new();
    let mut reductions = Vec::new();
    for ds in active() {
        let data = GnnData::build(&ds, SEED);
        // The GNN hot-spot op: Â · TopK(X) with the feature top-k mask.
        let rhs = sparsify::topk_abs_csr(&data.features, TOPK);
        // All GNN tiers share one device config (GNN_SIM_SCALE): the
        // Fig. 9 mechanism is working-set growth against *fixed* caches.
        let mut on = SpgemmExecutor::simulated_scaled(Variant::HashAia, GNN_SIM_SCALE);
        let mut off = SpgemmExecutor::simulated_scaled(Variant::Hash, GNN_SIM_SCALE);
        on.multiply(&data.adj_gcn, &rhs);
        off.multiply(&data.adj_gcn, &rhs);
        let red = reduction_pct(off.sim_ms, on.sim_ms);
        sizes.push(data.n as f64);
        reductions.push(red);
        t.row(&[
            ds.paper.name.to_string(),
            data.n.to_string(),
            data.adj.nnz().to_string(),
            format!("{:.2}", off.sim_ms),
            format!("{:.2}", on.sim_ms),
            format!("{red:.1}%"),
        ]);
        let mut o = Json::obj();
        o.set("name", ds.paper.name.into());
        o.set("nodes", data.n.into());
        o.set("edges", data.adj.nnz().into());
        o.set("noaia_ms", off.sim_ms.into());
        o.set("aia_ms", on.sim_ms.into());
        o.set("reduction_pct", red.into());
        out.push(o);
    }
    let r = pearson(&sizes, &reductions);
    println!("\nPearson r (size vs reduction): {r:.3} (paper: 0.94)");
    let mut wrapper = Json::obj();
    wrapper.set("rows", out);
    wrapper.set("pearson_r", r.into());
    save_json("fig9", &wrapper);
    wrapper
}

/// One (dataset × arch) training measurement for Figs. 10–11.
pub struct TrainMeasurement {
    pub dataset: String,
    pub arch: Arch,
    pub epochs: usize,
    pub final_loss: f32,
    pub final_acc: f64,
    /// Host wall time of the PJRT dense path (reported, not compared —
    /// the CPU PJRT backend is not the H200).
    pub dense_secs_per_epoch: f64,
    /// *Estimated H200 time* of the dense path (memory-bound model, see
    /// `dense_gpu_ms`) — the component that is identical across variants.
    pub dense_gpu_ms: f64,
    /// Simulated SpGEMM ms/epoch per variant [AIA, noAIA, ESC].
    pub spgemm_ms: [f64; 3],
    /// Fraction of the functional trainer's aggregations served from a
    /// reused symbolic plan (plan-reuse batched execution).
    pub plan_hit_rate: f64,
}

impl TrainMeasurement {
    /// Per-epoch training time for a variant, ms (simulated dense +
    /// simulated sparse; only the SpGEMM engine changes across variants,
    /// exactly the paper's setting).
    pub fn epoch_ms(&self, v: Variant) -> f64 {
        let idx = match v {
            Variant::HashAia => 0,
            Variant::Hash => 1,
            Variant::Cusparse => 2,
        };
        self.dense_gpu_ms + self.spgemm_ms[idx]
    }
}

/// H200-estimated dense-path time per epoch. The d=64 layer matmuls are
/// memory-bound on an H200 (arithmetic intensity ≈ 32 FLOP/B ≪ machine
/// balance), so time ≈ bytes-moved / effective HBM bandwidth. Per epoch
/// the forward+backward touch each n×64 f32 tensor a small constant
/// number of times per op.
pub fn dense_gpu_ms(n: usize, arch: Arch) -> f64 {
    let tensor_bytes = (n * 64 * 4) as f64;
    // ops/epoch (fwd topk+layers+loss, bwd layers; GIN has 2 extra MLP
    // matmul pairs): ~3 tensor reads/writes per op.
    let ops = match arch {
        Arch::Gcn => 14.0,
        Arch::Gin => 22.0,
        Arch::Sage => 18.0,
    };
    let eff_bw_bytes_per_ms = 3.3e12 / 1e3; // ~70% of 4.8 TB/s
    ops * 3.0 * tensor_bytes / eff_bw_bytes_per_ms
}

/// Figs. 10 & 11: full-batch training-time reduction per dataset × arch.
pub fn fig10_fig11(rt: &mut Runtime) -> Result<Json> {
    println!("\n=== Fig 10/11: GNN training time reduction (3-layer, top-k pruning) ===");
    let t = Table::new(&[15, 6, 8, 8, 10, 10, 12, 12]);
    t.header(&["dataset", "arch", "loss", "acc", "dense ms", "spgemm ms", "vs noAIA", "vs cuSPARSE"]);
    let epochs = if quick() { 2 } else { 3 };
    let mut out = Json::Arr(vec![]);
    let mut vs_sw = Vec::new();
    let mut vs_esc = Vec::new();
    let mut hit_rates = Vec::new();
    for ds in active() {
        let data = GnnData::build(&ds, SEED);
        for arch in Arch::all() {
            let m = train_one(rt, &data, arch, epochs)?;
            let aia = m.epoch_ms(Variant::HashAia);
            let sw = m.epoch_ms(Variant::Hash);
            let esc = m.epoch_ms(Variant::Cusparse);
            let r_sw = reduction_pct(sw, aia);
            let r_esc = reduction_pct(esc, aia);
            vs_sw.push(r_sw);
            vs_esc.push(r_esc);
            t.row(&[
                ds.paper.name.to_string(),
                arch.name().to_string(),
                format!("{:.3}", m.final_loss),
                format!("{:.3}", m.final_acc),
                format!("{:.2}", m.dense_gpu_ms),
                format!("{:.1}", m.spgemm_ms[0]),
                format!("{r_sw:.1}%"),
                format!("{r_esc:.1}%"),
            ]);
            let mut o = Json::obj();
            o.set("dataset", ds.paper.name.into());
            o.set("arch", arch.name().into());
            o.set("final_loss", (m.final_loss as f64).into());
            o.set("final_acc", m.final_acc.into());
            o.set("dense_s_per_epoch_cpu_wall", m.dense_secs_per_epoch.into());
            o.set("dense_gpu_ms", m.dense_gpu_ms.into());
            o.set(
                "spgemm_ms",
                Json::Arr(vec![m.spgemm_ms[0].into(), m.spgemm_ms[1].into(), m.spgemm_ms[2].into()]),
            );
            o.set("reduction_vs_noaia_pct", r_sw.into());
            o.set("reduction_vs_cusparse_pct", r_esc.into());
            o.set("plan_hit_rate", m.plan_hit_rate.into());
            hit_rates.push(m.plan_hit_rate);
            out.push(o);
        }
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!(
        "\naverages: AIA vs software-only {:.1}% (paper: 30.3%), AIA vs cuSPARSE {:.1}% (paper: 48.6%)",
        avg(&vs_sw),
        avg(&vs_esc)
    );
    println!(
        "functional-trainer plan-reuse hit rate: {:.1}% of aggregations skipped the symbolic phase",
        100.0 * avg(&hit_rates)
    );
    save_json("fig10_fig11", &out);
    Ok(out)
}

/// Train one configuration and price its SpGEMM jobs under all variants.
pub fn train_one(rt: &mut Runtime, data: &GnnData, arch: Arch, epochs: usize) -> Result<TrainMeasurement> {
    let mut trainer = Trainer::new(rt, data, arch, SEED ^ 0xA1A);
    let mut last = None;
    for _ in 0..epochs {
        last = Some(trainer.epoch()?);
    }
    let stats = last.unwrap();
    let spgemm_ms = [
        trainer.simulate_epoch_ms(Variant::HashAia),
        trainer.simulate_epoch_ms(Variant::Hash),
        trainer.simulate_epoch_ms(Variant::Cusparse),
    ];
    Ok(TrainMeasurement {
        dataset: data.name.clone(),
        arch,
        epochs,
        final_loss: stats.loss,
        final_acc: stats.accuracy,
        dense_secs_per_epoch: stats.dense_secs,
        dense_gpu_ms: dense_gpu_ms(data.n, arch),
        spgemm_ms,
        plan_hit_rate: trainer.plan_hit_rate(),
    })
}
