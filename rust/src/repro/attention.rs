//! Sparse-attention repro leg (DESIGN.md §2i): masked SpGEMM under the
//! band and block masks of windowed / blockwise attention.
//!
//! Sparse attention computes `M ⊙ (Q·Kᵀ)` — the score matrix is never
//! needed outside the mask, so a masked SpGEMM that prunes both phases
//! should beat multiply-then-filter by roughly the density ratio. We
//! model the token-affinity product with a community power-law graph
//! (content-based attention clusters tokens) and run the same product
//! under a sliding-window band mask and a chunked block mask, reporting
//! engine wall time, simulated time, and HBM traffic (AIA on) for the
//! masked path against the multiply-then-filter oracle. The oracle's
//! simulated cost covers only its multiply — the filter pass is free in
//! the simulator — so the reported reductions are a lower bound.

use super::{quick, reduction_pct, save_json, Table, SEED};
use crate::gen::structured::{band_mask, block_mask, community_powerlaw};
use crate::sim::{simulate_stats_engine_cfg, AiaMode, SimConfig};
use crate::spgemm::hash::{self, EngineConfig, Mask};
use crate::util::json::Json;
use crate::util::Pcg32;

/// Simulated device scale for the synthetic attention workload (same
/// convention as the Table II dataset registry).
const SCALE: usize = 8;

/// Masked vs multiply-then-filter on band/block attention masks.
pub fn attention() -> Json {
    let n = if quick() { 512 } else { 2048 };
    let window = (n / 32).max(4);
    let block = (n / 16).max(8);
    println!("\n=== Sparse attention: C = M . (A*A), band/block masks (n = {n}) ===");
    let a = community_powerlaw(n, 16, 16, &mut Pcg32::new(SEED, 700));
    let masks: [(&str, crate::sparse::Csr); 2] =
        [("band", band_mask(n, window)), ("block", block_mask(n, block))];

    let t = Table::new(&[8, 9, 9, 11, 11, 10, 11, 11]);
    t.header(&[
        "mask",
        "mask d%",
        "nnz(C)",
        "masked ms",
        "oracle ms",
        "sim red%",
        "fetch MB",
        "o.fetch MB",
    ]);
    let sim_cfg = SimConfig::for_scale(AiaMode::On, SCALE);
    let full_report = simulate_stats_engine_cfg(&a, &a, &sim_cfg, &EngineConfig::default());
    let mut out = Json::Arr(vec![]);
    for (name, m_csr) in &masks {
        let mask = Mask::from_structure(m_csr);

        let t0 = std::time::Instant::now();
        let c = hash::multiply_masked(&a, &a, &mask);
        let masked_wall = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let oracle = mask.filter(&hash::multiply(&a, &a));
        let oracle_wall = t1.elapsed().as_secs_f64();
        assert_eq!(c, oracle, "masked product diverged from the oracle under the {name} mask");

        let cfg = EngineConfig { mask: Some(mask.clone()), ..EngineConfig::default() };
        let masked_report = simulate_stats_engine_cfg(&a, &a, &sim_cfg, &cfg);
        let red = reduction_pct(full_report.total_ms, masked_report.total_ms);
        let density = 100.0 * mask.nnz() as f64 / (n as f64 * n as f64);
        t.row(&[
            name.to_string(),
            format!("{density:.1}%"),
            c.nnz().to_string(),
            format!("{:.3}", masked_report.total_ms),
            format!("{:.3}", full_report.total_ms),
            format!("{red:.1}%"),
            format!("{:.2}", masked_report.fetched_bytes() as f64 / 1e6),
            format!("{:.2}", full_report.fetched_bytes() as f64 / 1e6),
        ]);
        println!(
            "  {name}: engine wall masked {:.3}s vs multiply-then-filter {:.3}s",
            masked_wall, oracle_wall
        );
        let mut o = Json::obj();
        o.set("mask", (*name).into());
        o.set("n", n.into());
        o.set("mask_nnz", mask.nnz().into());
        o.set("out_nnz", c.nnz().into());
        o.set("masked_sim_ms", masked_report.total_ms.into());
        o.set("full_sim_ms", full_report.total_ms.into());
        o.set("sim_reduction_pct", red.into());
        o.set("masked_fetched_bytes", (masked_report.fetched_bytes() as i64).into());
        o.set("full_fetched_bytes", (full_report.fetched_bytes() as i64).into());
        o.set("masked_wall_s", masked_wall.into());
        o.set("oracle_wall_s", oracle_wall.into());
        out.push(o);
    }
    save_json("attention", &out);
    out
}
