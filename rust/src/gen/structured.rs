//! Structured-matrix generators: the non-power-law entries of Table II.
//!
//! Each generator targets the *degree distribution and locality class* of
//! one SuiteSparse matrix family (road network, FEM mesh, protein contact
//! map, DNA electrophoresis cage, circuit, economics) — the properties
//! that drive SpGEMM behaviour — at a configurable scale.

use crate::sparse::{Coo, Csr};
use crate::util::Pcg32;

/// Road network: 2D lattice with degree ~2.8 (grid edges dropped at
/// random) and a sprinkle of highway shortcuts. Analogue of roadNet-TX.
pub fn road_grid(side: usize, rng: &mut Pcg32) -> Csr {
    let n = side * side;
    let mut coo = Coo::with_capacity(n, n, n * 4);
    for y in 0..side {
        for x in 0..side {
            let u = y * side + x;
            // Keep ~70% of lattice edges => avg degree ~2.8 undirected.
            if x + 1 < side && rng.coin(0.7) {
                let v = y * side + x + 1;
                coo.push(u, v, 1.0);
                coo.push(v, u, 1.0);
            }
            if y + 1 < side && rng.coin(0.7) {
                let v = (y + 1) * side + x;
                coo.push(u, v, 1.0);
                coo.push(v, u, 1.0);
            }
            // Rare long-range shortcut (ramps/bridges).
            if rng.coin(0.01) {
                let v = rng.below_usize(n);
                if v != u {
                    coo.push(u, v, 1.0);
                    coo.push(v, u, 1.0);
                }
            }
        }
    }
    coo.to_csr()
}

/// FEM / structural mesh (Wind Tunnel analogue): symmetric, banded, high
/// uniform degree (`deg` ≈ 53). Nodes connect to near neighbours in a
/// pseudo-3D ordering.
pub fn fem_banded(n: usize, deg: usize, rng: &mut Pcg32) -> Csr {
    let half = deg / 2;
    let mut coo = Coo::with_capacity(n, n, n * (deg + 1));
    for i in 0..n {
        coo.push(i, i, rng.f64_range(10.0, 20.0)); // strong diagonal
        let mut added = 0usize;
        let mut off = 1usize;
        while added < half && i + off < n {
            // Band with stochastic holes: FEM stencils are locally dense
            // but not full.
            if rng.coin(0.8) {
                let v = rng.f64_range(-1.0, 1.0);
                coo.push(i, i + off, v);
                coo.push(i + off, i, v);
                added += 1;
            }
            off += 1 + rng.below_usize(3);
        }
    }
    coo.to_csr()
}

/// Protein contact map analogue: very high average degree (~119), dense
/// diagonal blocks (secondary structure) plus long-range contacts.
pub fn protein_contact(n: usize, deg: usize, rng: &mut Pcg32) -> Csr {
    let block = 32usize;
    let mut coo = Coo::with_capacity(n, n, n * deg);
    for i in 0..n {
        coo.push(i, i, 1.0);
        // Dense local block.
        let b0 = (i / block) * block;
        for j in b0..(b0 + block).min(n) {
            if j != i && rng.coin(0.85) {
                coo.push(i, j, rng.f64_range(0.1, 1.0));
            }
        }
        // Long-range contacts to reach target degree.
        let extra = deg.saturating_sub(block);
        for _ in 0..extra {
            let j = rng.below_usize(n);
            if j != i {
                coo.push(i, j, rng.f64_range(0.1, 1.0));
            }
        }
    }
    coo.to_csr()
}

/// DNA electrophoresis "cage" analogue: near-regular degree (~19), narrow
/// degree spread, banded + few random couplings. cage matrices have very
/// low max/avg ratio (47/19.2 ≈ 2.4).
pub fn cage_regular(n: usize, deg: usize, rng: &mut Pcg32) -> Csr {
    let mut coo = Coo::with_capacity(n, n, n * (deg + 1));
    for i in 0..n {
        coo.push(i, i, rng.f64_range(0.5, 1.0));
        // deterministic band structure, slight jitter
        for k in 1..deg {
            let span = 1 + k * 3;
            let j = if k % 2 == 0 { i + span } else { i.wrapping_sub(span) };
            if j < n && rng.coin(0.95) {
                coo.push(i, j, rng.f64_range(0.01, 0.1));
            }
        }
    }
    coo.to_csr()
}

/// Circuit netlist analogue (scircuit): low average degree (~5.6), short
/// wires dominate, a few global nets (power rails) with large fan-out.
pub fn circuit(n: usize, rng: &mut Pcg32) -> Csr {
    let mut coo = Coo::with_capacity(n, n, n * 7);
    let n_global = (n / 500).max(1); // power/clock nets
    for i in 0..n {
        coo.push(i, i, rng.f64_range(1.0, 2.0));
        let local = 2 + rng.below_usize(6);
        for _ in 0..local {
            // mostly short-range wires
            let span = 1 + rng.powerlaw_index(n / 10, 2.2);
            let j = if rng.coin(0.5) { i + span } else { i.wrapping_sub(span) };
            if j < n && j != i {
                let v = rng.f64_range(-1.0, 1.0);
                coo.push(i, j, v);
            }
        }
        // connect to a global net occasionally
        if rng.coin(0.02) {
            let g = rng.below_usize(n_global);
            coo.push(i, g, 1.0);
            coo.push(g, i, 1.0);
        }
    }
    coo.to_csr()
}

/// Economics input-output model analogue: ~6 nnz/row, *tight* max (44) —
/// nearly uniform with mild clustering.
pub fn economics(n: usize, rng: &mut Pcg32) -> Csr {
    let mut coo = Coo::with_capacity(n, n, n * 7);
    for i in 0..n {
        coo.push(i, i, 1.0);
        let d = 3 + rng.below_usize(6);
        for _ in 0..d {
            let j = rng.below_usize(n);
            if j != i {
                coo.push(i, j, rng.f64_range(0.01, 1.0));
            }
        }
    }
    coo.to_csr()
}

/// P2P overlay network (p2p-Gnutella04 analogue): directed, avg degree
/// ~3.7, moderate hubs (max ~500 at full scale).
pub fn p2p(n: usize, rng: &mut Pcg32) -> Csr {
    let mut coo = Coo::with_capacity(n, n, n * 4);
    let n_hubs = (n / 200).max(1);
    for i in 0..n {
        let d = 1 + rng.below_usize(6);
        for _ in 0..d {
            // 20% of edges go to hub nodes (supernodes), rest uniform.
            let j = if rng.coin(0.2) { rng.below_usize(n_hubs) } else { rng.below_usize(n) };
            if j != i {
                coo.push(i, j, 1.0);
            }
        }
    }
    coo.to_csr()
}

/// Band mask for sparse attention (DESIGN.md §2i): structure-only
/// `n × n` Csr admitting `|i - j| <= bandwidth` — the sliding-window
/// pattern of Longformer-style local attention. Values are unit
/// (masks ignore them); `bandwidth = 0` is the diagonal.
pub fn band_mask(n: usize, bandwidth: usize) -> Csr {
    let mut rpt = Vec::with_capacity(n + 1);
    let mut col = Vec::new();
    rpt.push(0usize);
    for i in 0..n {
        let lo = i.saturating_sub(bandwidth);
        let hi = (i + bandwidth).min(n.saturating_sub(1));
        for j in lo..=hi {
            col.push(j as u32);
        }
        rpt.push(col.len());
    }
    let val = vec![1.0; col.len()];
    Csr::new_unchecked(n, n, rpt, col, val)
}

/// Block-diagonal mask for sparse attention (DESIGN.md §2i):
/// structure-only `n × n` Csr admitting `i/block == j/block` — the
/// chunked pattern of blockwise attention. The last block is ragged
/// when `block` does not divide `n`. Panics if `block == 0`.
pub fn block_mask(n: usize, block: usize) -> Csr {
    assert!(block > 0, "block_mask needs a positive block size");
    let mut rpt = Vec::with_capacity(n + 1);
    let mut col = Vec::new();
    rpt.push(0usize);
    for i in 0..n {
        let b0 = (i / block) * block;
        for j in b0..(b0 + block).min(n) {
            col.push(j as u32);
        }
        rpt.push(col.len());
    }
    let val = vec![1.0; col.len()];
    Csr::new_unchecked(n, n, rpt, col, val)
}

/// Symmetric random permutation `P·A·Pᵀ`: destroys the artificial
/// near-diagonal locality of synthetic constructions. SuiteSparse
/// exports use arbitrary node ids, which is what makes SpGEMM's
/// indirection cache-hostile — the paper's Fig. 5 baseline hit ratios
/// (35–65 %) assume that ordering.
pub fn permute_symmetric(m: &Csr, rng: &mut Pcg32) -> Csr {
    let mut p: Vec<u32> = (0..m.n_rows as u32).collect();
    rng.shuffle(&mut p);
    permute_symmetric_with(m, &p)
}

/// `P·A·Pᵀ` with a caller-supplied permutation (`p[old] = new`).
pub fn permute_symmetric_with(m: &Csr, p: &[u32]) -> Csr {
    assert_eq!(m.n_rows, m.n_cols);
    let n = m.n_rows;
    let mut coo = Coo::with_capacity(n, n, m.nnz());
    for i in 0..n {
        let (cs, vs) = m.row(i);
        for (&c, &v) in cs.iter().zip(vs) {
            coo.push(p[i] as usize, p[c as usize] as usize, v);
        }
    }
    coo.to_csr()
}

/// Preferential-attachment graph with locality — used for the GNN
/// social-network datasets (Flickr/Reddit/Yelp analogues) where degree is
/// power-law but edges cluster among communities.
pub fn community_powerlaw(n: usize, avg_deg: usize, n_comm: usize, rng: &mut Pcg32) -> Csr {
    let mut coo = Coo::with_capacity(n, n, n * avg_deg);
    let comm_size = n.div_ceil(n_comm);
    for i in 0..n {
        let my_comm = i / comm_size;
        let d = 1 + rng.powerlaw_index(avg_deg * 8, 2.3).min(avg_deg * 16);
        let d = ((d + avg_deg) / 2).max(1);
        for _ in 0..d {
            let j = if rng.coin(0.7) {
                // intra-community edge
                let base = my_comm * comm_size;
                base + rng.below_usize(comm_size.min(n - base))
            } else {
                rng.below_usize(n)
            };
            if j != i {
                coo.push(i, j, 1.0);
                coo.push(j, i, 1.0);
            }
        }
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::MatrixStats;

    #[test]
    fn road_grid_degree_close_to_paper() {
        let m = road_grid(100, &mut Pcg32::seeded(1));
        let s = MatrixStats::of(&m);
        assert_eq!(s.rows, 10_000);
        assert!((s.avg_nnz_row - 2.8).abs() < 0.5, "avg={}", s.avg_nnz_row);
        assert!(s.max_nnz_row < 60);
    }

    #[test]
    fn fem_banded_high_uniform_degree() {
        let m = fem_banded(5000, 53, &mut Pcg32::seeded(2));
        let s = MatrixStats::of(&m);
        assert!(s.avg_nnz_row > 30.0 && s.avg_nnz_row < 70.0, "avg={}", s.avg_nnz_row);
        // tight spread like Wind Tunnel (max/avg ≈ 3.4)
        assert!((s.max_nnz_row as f64) < 5.0 * s.avg_nnz_row);
        // symmetric
        assert!(m.approx_eq(&m.transpose(), 1e-12));
    }

    #[test]
    fn protein_very_dense_rows() {
        let m = protein_contact(2000, 119, &mut Pcg32::seeded(3));
        let s = MatrixStats::of(&m);
        assert!(s.avg_nnz_row > 80.0, "avg={}", s.avg_nnz_row);
        assert!((s.max_nnz_row as f64) < 3.0 * s.avg_nnz_row);
    }

    #[test]
    fn cage_regular_tight_spread() {
        let m = cage_regular(5000, 19, &mut Pcg32::seeded(4));
        let s = MatrixStats::of(&m);
        assert!((s.avg_nnz_row - 19.0).abs() < 4.0, "avg={}", s.avg_nnz_row);
        assert!((s.max_nnz_row as f64) < 2.5 * s.avg_nnz_row, "max={}", s.max_nnz_row);
    }

    #[test]
    fn circuit_has_global_nets() {
        let m = circuit(20_000, &mut Pcg32::seeded(5));
        let s = MatrixStats::of(&m);
        assert!((s.avg_nnz_row - 5.6).abs() < 2.5, "avg={}", s.avg_nnz_row);
        // hubs exist but are bounded (scircuit: max 353 at 171k rows)
        assert!(s.max_nnz_row > 20 && s.max_nnz_row < 2000, "max={}", s.max_nnz_row);
    }

    #[test]
    fn economics_tight_max() {
        let m = economics(10_000, &mut Pcg32::seeded(6));
        let s = MatrixStats::of(&m);
        assert!((s.avg_nnz_row - 6.2).abs() < 2.0);
        assert!(s.max_nnz_row < 44, "max={}", s.max_nnz_row);
    }

    #[test]
    fn p2p_has_supernodes() {
        let m = p2p(10_000, &mut Pcg32::seeded(7));
        let t = m.transpose(); // in-degree hubs
        let s = MatrixStats::of(&t);
        assert!((s.max_nnz_row as f64) > 10.0 * s.avg_nnz_row, "max={} avg={}", s.max_nnz_row, s.avg_nnz_row);
    }

    #[test]
    fn community_graph_is_symmetric_and_clustered() {
        let m = community_powerlaw(4000, 22, 16, &mut Pcg32::seeded(8));
        assert!(m.approx_eq(&m.transpose(), 1e-12));
        let s = MatrixStats::of(&m);
        assert!(s.avg_nnz_row > 10.0, "avg={}", s.avg_nnz_row);
    }

    #[test]
    fn band_mask_admits_exactly_the_band() {
        let m = band_mask(7, 2);
        assert_eq!(m.n_rows, 7);
        for i in 0..7usize {
            let (cols, _) = m.row(i);
            let expect: Vec<u32> =
                (i.saturating_sub(2)..=(i + 2).min(6)).map(|j| j as u32).collect();
            assert_eq!(cols, expect.as_slice(), "row {i}");
        }
        // bandwidth 0 is the identity structure
        let d = band_mask(5, 0);
        assert_eq!(d.nnz(), 5);
        assert!(d.approx_eq(&Csr::identity(5), 1e-12));
        // bandwidth >= n-1 is full
        assert_eq!(band_mask(6, 5).nnz(), 36);
    }

    #[test]
    fn block_mask_admits_exactly_the_blocks() {
        let m = block_mask(10, 4); // blocks of 4, 4, ragged 2
        assert_eq!(m.nnz(), 16 + 16 + 4);
        for i in 0..10usize {
            let b0 = (i / 4) * 4;
            let (cols, _) = m.row(i);
            let expect: Vec<u32> = (b0..(b0 + 4).min(10)).map(|j| j as u32).collect();
            assert_eq!(cols, expect.as_slice(), "row {i}");
        }
        // block >= n is full; block 1 is the diagonal
        assert_eq!(block_mask(5, 8).nnz(), 25);
        assert!(block_mask(5, 1).approx_eq(&Csr::identity(5), 1e-12));
    }

    #[test]
    fn generators_are_deterministic() {
        assert_eq!(circuit(1000, &mut Pcg32::seeded(9)), circuit(1000, &mut Pcg32::seeded(9)));
        assert_eq!(
            fem_banded(1000, 20, &mut Pcg32::seeded(9)),
            fem_banded(1000, 20, &mut Pcg32::seeded(9))
        );
    }
}
