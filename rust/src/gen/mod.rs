//! Synthetic matrix and graph generators — stand-ins for the paper's
//! SuiteSparse (Table II) and OGB/GraphSAINT (Table III) datasets, which
//! are not available offline. Each generator targets the degree
//! distribution and locality class of its real counterpart; the registry
//! records the paper-side stats next to the substitution.

pub mod registry;
pub mod rmat;
pub mod structured;

pub use registry::{table2_by_name, table2_datasets, table3_by_name, table3_datasets, Dataset, GnnDataset};
pub use rmat::{rmat, RmatParams};
