//! Dataset registry: synthetic analogues of every matrix in the paper's
//! Table II and every GNN dataset in Table III, at documented scales.
//!
//! Each entry records the *paper's* characteristics alongside the
//! generator + scale we substitute (DESIGN.md §Hardware substitution).
//! Scales are chosen so the heaviest self-product stays within tens of
//! millions of intermediate products — large enough to exercise every
//! group of the row-grouping phase, small enough to simulate.

use super::rmat::{rmat, RmatParams};
use super::structured::*;
use crate::sparse::Csr;
use crate::util::Pcg32;

/// Paper-side characteristics of a Table II matrix (for reporting).
#[derive(Clone, Copy, Debug)]
pub struct PaperMatrix {
    pub name: &'static str,
    pub rows: usize,
    pub nnz: usize,
    pub nnz_per_row: f64,
    pub max_nnz_row: usize,
    pub ip_a2: u64,
    pub nnz_a2: u64,
}

/// One Table II dataset: paper stats + our synthetic generator.
pub struct Dataset {
    pub paper: PaperMatrix,
    /// Scale divisor relative to the paper's row count (documentation).
    pub scale: usize,
    pub gen: fn(u64) -> Csr,
}

/// The 12 matrices of Table II, in paper order.
pub fn table2_datasets() -> Vec<Dataset> {
    vec![
        Dataset {
            paper: PaperMatrix {
                name: "RoadTX",
                rows: 1_393_383,
                nnz: 3_843_320,
                nnz_per_row: 2.8,
                max_nnz_row: 51,
                ip_a2: 12_099_370,
                nnz_a2: 3_843_320,
            },
            scale: 20,
            // 264^2 ≈ 70k rows, arbitrary ids
            gen: |seed| {
                let mut r = Pcg32::new(seed, 10);
                let m = road_grid(264, &mut r);
                permute_symmetric(&m, &mut r)
            },
        },
        Dataset {
            paper: PaperMatrix {
                name: "p2p-Gnutella04",
                rows: 10_879,
                nnz: 39_994,
                nnz_per_row: 3.7,
                max_nnz_row: 497,
                ip_a2: 180_230,
                nnz_a2: 39_994,
            },
            scale: 1, // small enough to keep at full scale
            gen: |seed| p2p(10_879, &mut Pcg32::new(seed, 11)),
        },
        Dataset {
            paper: PaperMatrix {
                name: "amazon0601",
                rows: 403_394,
                nnz: 3_387_388,
                nnz_per_row: 8.4,
                max_nnz_row: 100,
                ip_a2: 32_373_599,
                nnz_a2: 16_258_436,
            },
            scale: 8,
            gen: |seed| community_powerlaw(50_424, 4, 64, &mut Pcg32::new(seed, 12)),
        },
        Dataset {
            paper: PaperMatrix {
                name: "web-Google",
                rows: 916_428,
                nnz: 5_105_039,
                nnz_per_row: 5.6,
                max_nnz_row: 4334,
                ip_a2: 60_687_836,
                nnz_a2: 29_710_164,
            },
            scale: 16,
            gen: |seed| rmat(57_276, 320_000, RmatParams::web(), &mut Pcg32::new(seed, 13)),
        },
        Dataset {
            paper: PaperMatrix {
                name: "scircuit",
                rows: 170_998,
                nnz: 958_936,
                nnz_per_row: 5.6,
                max_nnz_row: 353,
                ip_a2: 8_676_313,
                nnz_a2: 5_222_525,
            },
            scale: 4,
            gen: |seed| {
                let mut r = Pcg32::new(seed, 14);
                let m = circuit(42_749, &mut r);
                permute_symmetric(&m, &mut r)
            },
        },
        Dataset {
            paper: PaperMatrix {
                name: "cit-Patents",
                rows: 3_774_768,
                nnz: 16_518_948,
                nnz_per_row: 4.4,
                max_nnz_row: 770,
                ip_a2: 82_152_992,
                nnz_a2: 68_848_721,
            },
            scale: 48,
            gen: |seed| rmat(78_641, 345_000, RmatParams::citation(), &mut Pcg32::new(seed, 15)),
        },
        Dataset {
            paper: PaperMatrix {
                name: "Economics",
                rows: 206_500,
                nnz: 1_273_389,
                nnz_per_row: 6.2,
                max_nnz_row: 44,
                ip_a2: 7_556_897,
                nnz_a2: 6_704_899,
            },
            scale: 4,
            gen: |seed| economics(51_625, &mut Pcg32::new(seed, 16)),
        },
        Dataset {
            paper: PaperMatrix {
                name: "webbase-1M",
                rows: 1_000_005,
                nnz: 3_105_536,
                nnz_per_row: 3.1,
                max_nnz_row: 4700,
                ip_a2: 69_524_195,
                nnz_a2: 51_111_996,
            },
            scale: 16,
            gen: |seed| {
                let params = RmatParams { a: 0.63, b: 0.17, c: 0.17, noise: 0.08 };
                rmat(62_500, 195_000, params, &mut Pcg32::new(seed, 17))
            },
        },
        Dataset {
            paper: PaperMatrix {
                name: "wb-edu",
                rows: 9_845_725,
                nnz: 57_156_537,
                nnz_per_row: 5.8,
                max_nnz_row: 3841,
                ip_a2: 1_559_579_990,
                nnz_a2: 630_077_764,
            },
            scale: 96,
            gen: |seed| rmat(102_560, 595_000, RmatParams::web(), &mut Pcg32::new(seed, 18)),
        },
        Dataset {
            paper: PaperMatrix {
                name: "cage15",
                rows: 5_154_859,
                nnz: 99_199_551,
                nnz_per_row: 19.2,
                max_nnz_row: 47,
                ip_a2: 2_078_631_615,
                nnz_a2: 929_023_247,
            },
            scale: 64,
            gen: |seed| {
                let mut r = Pcg32::new(seed, 19);
                let m = cage_regular(80_544, 19, &mut r);
                permute_symmetric(&m, &mut r)
            },
        },
        Dataset {
            paper: PaperMatrix {
                name: "WindTunnel",
                rows: 217_918,
                nnz: 11_634_424,
                nnz_per_row: 53.4,
                max_nnz_row: 180,
                ip_a2: 626_054_402,
                nnz_a2: 32_772_236,
            },
            scale: 8,
            gen: |seed| fem_banded(27_240, 53, &mut Pcg32::new(seed, 20)),
        },
        Dataset {
            paper: PaperMatrix {
                name: "Protein",
                rows: 36_417,
                nnz: 4_344_765,
                nnz_per_row: 119.3,
                max_nnz_row: 204,
                ip_a2: 555_322_659,
                nnz_a2: 19_594_581,
            },
            scale: 4,
            gen: |seed| {
                let mut r = Pcg32::new(seed, 21);
                let m = protein_contact(9_104, 119, &mut r);
                permute_symmetric(&m, &mut r)
            },
        },
    ]
}

/// Look up one Table II dataset by (case-insensitive) name.
pub fn table2_by_name(name: &str) -> Option<Dataset> {
    table2_datasets().into_iter().find(|d| d.paper.name.eq_ignore_ascii_case(name))
}

/// Paper-side characteristics of a Table III GNN dataset.
#[derive(Clone, Copy, Debug)]
pub struct PaperGnnDataset {
    pub name: &'static str,
    pub nodes: usize,
    pub edges: usize,
    pub avg_degree: f64,
    pub density_pct: f64,
    pub category: &'static str,
}

/// One Table III dataset analogue: scaled node count (one of the artifact
/// tiers) and the degree we generate at.
pub struct GnnDataset {
    pub paper: PaperGnnDataset,
    /// Scaled node count — must be one of the AOT artifact tiers.
    pub nodes: usize,
    /// Down-scaling factor vs the paper (paper nodes / nodes, rounded) —
    /// drives the simulated device's cache scaling.
    pub scale: usize,
    /// Generated average degree (paper degree, capped for the two
    /// super-dense graphs so edge counts stay simulable; ordering is
    /// preserved: Proteins and Reddit stay the densest by a wide margin).
    pub avg_degree: usize,
    pub gen: fn(u64) -> Csr,
}

/// The 6 GNN datasets of Table III, in paper order.
pub fn table3_datasets() -> Vec<GnnDataset> {
    vec![
        GnnDataset {
            paper: PaperGnnDataset {
                name: "Flickr",
                nodes: 89_250,
                edges: 989_006,
                avg_degree: 22.16,
                density_pct: 0.0248,
                category: "Social",
            },
            nodes: 8192,
            scale: 11,
            avg_degree: 22,
            gen: |seed| community_powerlaw(8192, 11, 32, &mut Pcg32::new(seed, 30)),
        },
        GnnDataset {
            paper: PaperGnnDataset {
                name: "ogbn-proteins",
                nodes: 132_534,
                edges: 79_122_504,
                avg_degree: 1193.92,
                density_pct: 0.9005,
                category: "Biological",
            },
            nodes: 8192,
            scale: 16,
            avg_degree: 300,
            gen: |seed| protein_contact(8192, 300, &mut Pcg32::new(seed, 31)),
        },
        GnnDataset {
            paper: PaperGnnDataset {
                name: "ogbn-arxiv",
                nodes: 169_343,
                edges: 1_335_586,
                avg_degree: 15.77,
                density_pct: 0.0093,
                category: "Citation",
            },
            nodes: 16384,
            scale: 10,
            avg_degree: 16,
            gen: |seed| rmat(16384, 262_000, RmatParams::citation(), &mut Pcg32::new(seed, 32)),
        },
        GnnDataset {
            paper: PaperGnnDataset {
                name: "Reddit",
                nodes: 232_965,
                edges: 114_848_857,
                avg_degree: 985.99,
                density_pct: 0.4232,
                category: "Social",
            },
            nodes: 16384,
            scale: 14,
            avg_degree: 250,
            gen: |seed| community_powerlaw(16384, 125, 64, &mut Pcg32::new(seed, 33)),
        },
        GnnDataset {
            paper: PaperGnnDataset {
                name: "Yelp",
                nodes: 716_847,
                edges: 13_954_819,
                avg_degree: 38.93,
                density_pct: 0.0054,
                category: "Social",
            },
            nodes: 32_768,
            scale: 22,
            avg_degree: 39,
            gen: |seed| community_powerlaw(32_768, 20, 128, &mut Pcg32::new(seed, 34)),
        },
        GnnDataset {
            paper: PaperGnnDataset {
                name: "ogbn-products",
                nodes: 2_449_029,
                edges: 126_167_053,
                avg_degree: 103.05,
                density_pct: 0.0042,
                category: "E-commerce",
            },
            nodes: 65_536,
            scale: 37,
            avg_degree: 103,
            gen: |seed| community_powerlaw(65_536, 52, 256, &mut Pcg32::new(seed, 35)),
        },
    ]
}

/// Look up one Table III dataset by name.
pub fn table3_by_name(name: &str) -> Option<GnnDataset> {
    table3_datasets().into_iter().find(|d| d.paper.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::MatrixStats;

    #[test]
    fn registry_has_all_twelve() {
        let names: Vec<_> = table2_datasets().iter().map(|d| d.paper.name).collect();
        assert_eq!(names.len(), 12);
        assert!(names.contains(&"scircuit"));
        assert!(names.contains(&"cage15"));
    }

    #[test]
    fn lookup_is_case_insensitive() {
        assert!(table2_by_name("SCIRCUIT").is_some());
        assert!(table2_by_name("nope").is_none());
        assert!(table3_by_name("flickr").is_some());
    }

    #[test]
    fn scaled_degree_tracks_paper_degree() {
        // Spot-check 3 cheap datasets: generated avg nnz/row within 2.5x
        // band of the paper's (structure class matters more than the exact
        // constant, but it should be close).
        for name in ["RoadTX", "Economics", "cage15"] {
            let d = table2_by_name(name).unwrap();
            let m = (d.gen)(1234);
            let s = MatrixStats::of(&m);
            let ratio = s.avg_nnz_row / d.paper.nnz_per_row;
            assert!(
                (0.4..2.5).contains(&ratio),
                "{name}: generated avg {} vs paper {}",
                s.avg_nnz_row,
                d.paper.nnz_per_row
            );
        }
    }

    #[test]
    fn gnn_tiers_are_artifact_tiers() {
        for d in table3_datasets() {
            assert!([8192usize, 16384, 32_768, 65_536].contains(&d.nodes), "{}", d.nodes);
        }
    }
}
