//! R-MAT (recursive matrix) graph generator — produces the power-law
//! degree distributions of the paper's web / citation / social matrices
//! (web-Google, cit-Patents, webbase-1M, wb-edu, amazon0601).

use crate::sparse::{Coo, Csr};
use crate::util::Pcg32;

/// R-MAT parameters. `(a, b, c)` are the quadrant probabilities
/// (d = 1 - a - b - c). Larger `a` ⇒ heavier skew (bigger hubs).
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Per-level probability perturbation, which avoids the artificial
    /// "staircase" degree plateaus of pure R-MAT.
    pub noise: f64,
}

impl RmatParams {
    /// Kronecker parameters close to Graph500's, for web-like graphs.
    pub fn web() -> Self {
        RmatParams { a: 0.57, b: 0.19, c: 0.19, noise: 0.05 }
    }

    /// Milder skew, citation-network-like.
    pub fn citation() -> Self {
        RmatParams { a: 0.45, b: 0.22, c: 0.22, noise: 0.05 }
    }

    /// Near-uniform (Erdős–Rényi-ish) for low-skew matrices.
    pub fn uniform() -> Self {
        RmatParams { a: 0.25, b: 0.25, c: 0.25, noise: 0.0 }
    }
}

/// Generate an `n × n` R-MAT matrix with ~`nnz_target` non-zeros (before
/// dedup; values uniform in [0.5, 1.5]). `n` is rounded up to a power of
/// two internally; indices outside `n` are rejected.
pub fn rmat(n: usize, nnz_target: usize, p: RmatParams, rng: &mut Pcg32) -> Csr {
    assert!(n > 0);
    let levels = (usize::BITS - (n - 1).leading_zeros()).max(1) as usize;
    let mut coo = Coo::with_capacity(n, n, nnz_target);
    let mut produced = 0usize;
    let max_attempts = nnz_target * 4;
    let mut attempts = 0usize;
    while produced < nnz_target && attempts < max_attempts {
        attempts += 1;
        let (mut r, mut c) = (0usize, 0usize);
        for _ in 0..levels {
            // Perturb quadrant probabilities per level.
            let na = p.a * (1.0 + p.noise * (rng.f64() - 0.5));
            let nb = p.b * (1.0 + p.noise * (rng.f64() - 0.5));
            let nc = p.c * (1.0 + p.noise * (rng.f64() - 0.5));
            let total = na + nb + nc + (1.0 - p.a - p.b - p.c).max(0.0);
            let u = rng.f64() * total;
            let (dr, dc) = if u < na {
                (0, 0)
            } else if u < na + nb {
                (0, 1)
            } else if u < na + nb + nc {
                (1, 0)
            } else {
                (1, 1)
            };
            r = (r << 1) | dr;
            c = (c << 1) | dc;
        }
        if r < n && c < n {
            coo.push(r, c, rng.f64_range(0.5, 1.5));
            produced += 1;
        }
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::MatrixStats;

    #[test]
    fn rmat_shape_and_nnz() {
        let mut rng = Pcg32::seeded(1);
        let m = rmat(1000, 8000, RmatParams::web(), &mut rng);
        assert_eq!(m.n_rows, 1000);
        assert_eq!(m.n_cols, 1000);
        // Dedup loses some, rejection a few more; expect within 30%.
        assert!(m.nnz() > 5000 && m.nnz() <= 8000, "nnz={}", m.nnz());
        assert!(m.validate().is_ok());
    }

    #[test]
    fn web_params_are_skewed() {
        let mut rng = Pcg32::seeded(2);
        let m = rmat(2048, 20_000, RmatParams::web(), &mut rng);
        let s = MatrixStats::of(&m);
        // Hubs: max row far above the average.
        assert!(
            (s.max_nnz_row as f64) > 8.0 * s.avg_nnz_row,
            "max {} avg {}",
            s.max_nnz_row,
            s.avg_nnz_row
        );
    }

    #[test]
    fn uniform_params_are_flat() {
        let mut rng = Pcg32::seeded(3);
        let m = rmat(2048, 20_000, RmatParams::uniform(), &mut rng);
        let s = MatrixStats::of(&m);
        assert!(
            (s.max_nnz_row as f64) < 6.0 * s.avg_nnz_row,
            "max {} avg {}",
            s.max_nnz_row,
            s.avg_nnz_row
        );
    }

    #[test]
    fn deterministic_for_seed() {
        let a = rmat(512, 4000, RmatParams::web(), &mut Pcg32::seeded(7));
        let b = rmat(512, 4000, RmatParams::web(), &mut Pcg32::seeded(7));
        assert_eq!(a, b);
    }
}
