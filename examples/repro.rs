//! Regenerate every table and figure of the paper's evaluation section
//! (DESIGN.md §4 experiment index). Equivalent to `spgemm-aia repro all`.
//!
//! ```bash
//! make artifacts && cargo run --release --example repro          # full
//! REPRO_QUICK=1 cargo run --release --example repro              # subset
//! ```

use spgemm_aia::repro;
use spgemm_aia::runtime::Runtime;

fn main() -> spgemm_aia::util::error::Result<()> {
    let t0 = std::time::Instant::now();
    repro::table2();
    repro::table3();
    repro::fig5();
    repro::fig6();
    repro::fig7_fig8();
    repro::fig9();
    repro::plan_reuse();
    if cfg!(feature = "pjrt") {
        match Runtime::new(&Runtime::artifacts_dir()) {
            Ok(mut rt) => {
                repro::fig10_fig11(&mut rt)?;
            }
            Err(e) => {
                eprintln!("skipping Fig 10/11 (PJRT client unavailable): {e}");
            }
        }
    } else {
        eprintln!("skipping Fig 10/11: built without the `pjrt` feature");
    }
    println!("\nall experiments regenerated in {:.1}s — JSON in target/repro/", t0.elapsed().as_secs_f64());
    Ok(())
}
