//! Quickstart: build a sparse matrix, run the paper's hash-based
//! multi-phase SpGEMM on the simulated AIA machine, and compare the
//! three system variants (hash+AIA / hash / cuSPARSE-ESC).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use spgemm_aia::coordinator::executor::{SpgemmExecutor, Variant};
use spgemm_aia::gen::{rmat, RmatParams};
use spgemm_aia::sim::gflops;
use spgemm_aia::spgemm::{ip, reference::spgemm_reference};
use spgemm_aia::util::Pcg32;

fn main() {
    // 1. A power-law matrix (the paper's problem class).
    let mut rng = Pcg32::seeded(7);
    let a = rmat(20_000, 160_000, RmatParams::web(), &mut rng);
    println!("A: {}x{}, {} nnz", a.n_rows, a.n_cols, a.nnz());

    // 2. Exact self-product with the hash engine; verify vs the oracle.
    let c = spgemm_aia::spgemm::hash::multiply(&a, &a);
    let oracle = spgemm_reference(&a, &a);
    assert!(c.approx_eq(&oracle, 1e-10), "engine must match the reference");
    let total_ip = ip::total_ip(&a, &a);
    println!("A^2: {} nnz from {} intermediate products (verified vs oracle)", c.nnz(), total_ip);

    // 3. Price the same product on the simulated H200 under each variant.
    println!("\n{:<16} {:>12} {:>12} {:>10}", "variant", "sim time", "GFLOPS", "L1 hit");
    for v in Variant::all() {
        let mut ex = SpgemmExecutor::simulated(v);
        let _ = ex.multiply(&a, &a);
        let report = &ex.reports[0];
        println!(
            "{:<16} {:>9.3} ms {:>12.1} {:>9.1}%",
            v.name(),
            ex.sim_ms,
            gflops(total_ip, ex.sim_ms),
            100.0 * report.l1_hit_ratio()
        );
    }
    println!("\nAIA turns the two-level indirection into sequential streams —");
    println!("higher L1 hit ratio, lower time (paper §IV). Try `spgemm-aia repro all`.");
}
