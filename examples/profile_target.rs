fn main() {
    use spgemm_aia::gen::{rmat, RmatParams};
    use spgemm_aia::util::Pcg32;
    let a = rmat(30_000, 300_000, RmatParams::web(), &mut Pcg32::seeded(2));
    let t0 = std::time::Instant::now();
    let c = spgemm_aia::spgemm::hash::multiply(&a, &a);
    println!("nnz={} in {:?}", c.nnz(), t0.elapsed());
}
