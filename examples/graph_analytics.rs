//! Graph-analytics workloads from the paper's §V: Markov Clustering and
//! Graph Contraction on Table-II dataset analogues, comparing all three
//! system variants (paper Figs. 7–8).
//!
//! ```bash
//! cargo run --release --example graph_analytics [dataset]
//! ```

use spgemm_aia::apps::{contract, mcl, random_labels, MclParams};
use spgemm_aia::coordinator::executor::{SpgemmExecutor, Variant};
use spgemm_aia::util::Pcg32;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "Economics".to_string());
    let ds = spgemm_aia::gen::table2_by_name(&name).expect("unknown Table II dataset");
    let g = (ds.gen)(20250710);
    println!(
        "dataset {name}: {} nodes, {} nnz (analogue of {} rows at 1/{})",
        g.n_rows,
        g.nnz(),
        ds.paper.rows,
        ds.scale
    );

    // ---- Markov Clustering (Algorithm 6) ----
    println!("\n== Markov Clustering ==");
    let params = MclParams { max_iters: 6, tol: 1e-4, top_k: 16, ..Default::default() };
    let mut base: Option<Vec<usize>> = None;
    for v in Variant::all() {
        let mut ex = SpgemmExecutor::simulated_scaled(v, ds.scale);
        let r = mcl(&g, &params, &mut ex);
        let first = base.get_or_insert_with(|| r.clusters.clone());
        assert_eq!(*first, r.clusters, "variants must agree functionally");
        println!(
            "{:<16} {} clusters, {} iterations, simulated SpGEMM {:.2} ms",
            v.name(),
            r.n_clusters,
            r.iterations,
            r.sim_ms
        );
    }

    // ---- Graph Contraction (Algorithm 7) ----
    println!("\n== Graph Contraction ==");
    let mut rng = Pcg32::seeded(99);
    let labels = random_labels(g.n_rows, (g.n_rows / 4).max(1), &mut rng);
    for v in Variant::all() {
        let mut ex = SpgemmExecutor::simulated_scaled(v, ds.scale);
        let r = contract(&g, &labels, &mut ex);
        println!(
            "{:<16} {} -> {} nodes ({} nnz), simulated SpGEMM {:.2} ms",
            v.name(),
            g.n_rows,
            r.contracted.n_rows,
            r.contracted.nnz(),
            r.sim_ms
        );
    }
}
