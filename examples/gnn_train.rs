//! End-to-end driver: hybrid full-batch GNN training (paper §V-C) —
//! proves all three layers compose:
//!
//! - **L1** Pallas `topk_mask` artifact prunes features (Eq. 2),
//! - **L3** hash SpGEMM aggregates `Â · TopK(X)` (Eq. 1), simulated on
//!   the AIA machine model,
//! - **L2** JAX layer/loss artifacts run the dense math through PJRT,
//!
//! and logs the loss curve plus the per-variant simulated SpGEMM time
//! (the Fig. 10/11 measurement). Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example gnn_train [dataset] [arch] [epochs]
//! ```

use spgemm_aia::coordinator::executor::Variant;
use spgemm_aia::gnn::{Arch, GnnData, Trainer};
use spgemm_aia::runtime::Runtime;

fn main() -> spgemm_aia::util::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(|s| s.as_str()).unwrap_or("Flickr");
    let arch = Arch::parse(args.get(1).map(|s| s.as_str()).unwrap_or("gcn")).expect("arch: gcn|gin|sage");
    let epochs: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(60);

    let ds = spgemm_aia::gen::table3_by_name(dataset).expect("unknown Table III dataset");
    let data = GnnData::build(&ds, 20250710);
    println!(
        "=== hybrid {} training on {} ({} nodes, {} edges, analogue of {} @ 1/{}) ===",
        arch.name(),
        dataset,
        data.n,
        data.adj.nnz(),
        ds.paper.nodes,
        ds.scale
    );

    let mut rt = Runtime::new(&Runtime::artifacts_dir())?;
    let mut trainer = Trainer::new(&mut rt, &data, arch, 42);
    trainer.lr = 2.0;

    let t0 = std::time::Instant::now();
    let mut first_loss = None;
    let mut last = None;
    for e in 0..epochs {
        let s = trainer.epoch()?;
        first_loss.get_or_insert(s.loss);
        if e % 5 == 0 || e + 1 == epochs {
            println!(
                "epoch {e:>4}: loss {:.4}  acc {:.3}  (dense wall {:.2}s, {} SpGEMM jobs)",
                s.loss, s.accuracy, s.dense_secs, s.spgemm_jobs
            );
        }
        last = Some(s);
    }
    let last = last.unwrap();
    println!("\ntrained {epochs} epochs in {:.1}s wall", t0.elapsed().as_secs_f64());
    println!(
        "loss {:.4} -> {:.4}; accuracy {:.1}% (chance = {:.1}%)",
        first_loss.unwrap(),
        last.loss,
        100.0 * last.accuracy,
        100.0 / 16.0
    );
    assert!(last.loss < first_loss.unwrap(), "loss must decrease");
    assert!(last.accuracy > 1.5 / 16.0, "accuracy must beat chance");

    // Fig 10/11 measurement on this configuration.
    println!("\nsimulated SpGEMM per epoch (H200 machine model):");
    let mut times = Vec::new();
    for v in Variant::all() {
        let ms = trainer.simulate_epoch_ms(v);
        println!("  {:<16} {:>8.2} ms", v.name(), ms);
        times.push(ms);
    }
    println!(
        "AIA reduces SpGEMM time {:.1}% vs software-only, {:.1}% vs cuSPARSE(ESC)",
        100.0 * (times[1] - times[0]) / times[1],
        100.0 * (times[2] - times[0]) / times[2]
    );
    Ok(())
}
